"""L1 kernel correctness: Pallas kernels vs the pure oracles in ref.py.

Hypothesis sweeps shapes (within the Pallas tiling constraints) and
random inputs; assert_allclose against the scalar-loop references is the
core correctness signal for the build-time layer.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

import jax.numpy as jnp

from compile.kernels.pairwise_dist import BLOCK_N, pairwise_sqdist
from compile.kernels.ref import ref_pairwise_sqdist, ref_surface_eval
from compile.kernels.spline_eval import refinement_vandermonde, surface_eval


# ---------------------------------------------------------------------------
# surface_eval
# ---------------------------------------------------------------------------
class TestVandermonde:
    def test_shape(self):
        v = refinement_vandermonde(4)
        assert v.shape == (16, 16)

    def test_row_zero_is_delta(self):
        # u = v = 0 -> only the constant term survives
        v = np.asarray(refinement_vandermonde(8))
        expected = np.zeros(16)
        expected[0] = 1.0
        assert_allclose(v[0], expected)

    def test_known_entry(self):
        rf = 4
        v = np.asarray(refinement_vandermonde(rf))
        # q = qi*rf + qj with qi=2, qj=3; k = 4a+b with a=3, b=1
        qi, qj, a, b = 2, 3, 3, 1
        assert_allclose(v[qi * rf + qj, 4 * a + b], (qi / rf) ** a * (qj / rf) ** b)


class TestSurfaceEval:
    @settings(max_examples=20, deadline=None)
    @given(
        s=st.integers(1, 4),
        gp1=st.integers(1, 5),
        gc1=st.integers(1, 5),
        rf=st.sampled_from([2, 4, 8]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_reference(self, s, gp1, gc1, rf, seed):
        rng = np.random.default_rng(seed)
        coeffs = rng.normal(size=(s, gp1, gc1, 16)).astype(np.float32)
        got = np.asarray(surface_eval(jnp.asarray(coeffs), rf=rf))
        want = ref_surface_eval(coeffs, rf)
        assert got.shape == (s, gp1 * rf, gc1 * rf)
        assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_constant_patch(self):
        coeffs = np.zeros((1, 2, 2, 16), dtype=np.float32)
        coeffs[..., 0] = 7.5
        got = np.asarray(surface_eval(jnp.asarray(coeffs), rf=4))
        assert_allclose(got, np.full((1, 8, 8), 7.5), rtol=1e-6)

    def test_linear_in_u(self):
        # f(u, v) = u  ->  dense[qi, :] = qi/rf
        coeffs = np.zeros((1, 1, 1, 16), dtype=np.float32)
        coeffs[0, 0, 0, 4] = 1.0  # k = 4*1+0
        got = np.asarray(surface_eval(jnp.asarray(coeffs), rf=8))[0]
        for qi in range(8):
            assert_allclose(got[qi], np.full(8, qi / 8), atol=1e-6)

    def test_linear_in_v(self):
        coeffs = np.zeros((1, 1, 1, 16), dtype=np.float32)
        coeffs[0, 0, 0, 1] = 1.0  # k = 4*0+1
        got = np.asarray(surface_eval(jnp.asarray(coeffs), rf=8))[0]
        for qj in range(8):
            assert_allclose(got[:, qj], np.full(8, qj / 8), atol=1e-6)

    def test_patch_locality(self):
        # coefficients of one patch must not leak into neighbours
        coeffs = np.zeros((1, 2, 2, 16), dtype=np.float32)
        coeffs[0, 1, 0, 0] = 3.0
        got = np.asarray(surface_eval(jnp.asarray(coeffs), rf=4))[0]
        assert_allclose(got[4:, :4], np.full((4, 4), 3.0))
        assert_allclose(got[:4, :], 0.0)
        assert_allclose(got[4:, 4:], 0.0)


# ---------------------------------------------------------------------------
# pairwise_sqdist
# ---------------------------------------------------------------------------
class TestPairwiseSqdist:
    @settings(max_examples=20, deadline=None)
    @given(
        nb=st.integers(1, 3),
        d=st.integers(1, 8),
        k=st.integers(1, 16),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_reference(self, nb, d, k, seed):
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(nb * BLOCK_N, d)).astype(np.float32)
        c = rng.normal(size=(k, d)).astype(np.float32)
        got = np.asarray(pairwise_sqdist(jnp.asarray(x), jnp.asarray(c)))
        want = ref_pairwise_sqdist(x, c)
        assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_zero_distance_on_centroid(self):
        c = np.arange(16, dtype=np.float32).reshape(4, 4)
        x = np.tile(c, (BLOCK_N // 4, 1))
        got = np.asarray(pairwise_sqdist(jnp.asarray(x), jnp.asarray(c)))
        idx = np.tile(np.arange(4), BLOCK_N // 4)
        assert_allclose(got[np.arange(BLOCK_N), idx], 0.0, atol=1e-3)

    def test_nonnegative(self):
        rng = np.random.default_rng(0)
        x = (1e3 * rng.normal(size=(BLOCK_N, 6))).astype(np.float32)
        got = np.asarray(pairwise_sqdist(jnp.asarray(x), jnp.asarray(x[:8])))
        assert (got >= 0).all()

    def test_rejects_misaligned_n(self):
        with pytest.raises(AssertionError):
            pairwise_sqdist(jnp.zeros((100, 4)), jnp.zeros((3, 4)))

    def test_rejects_dim_mismatch(self):
        with pytest.raises(AssertionError):
            pairwise_sqdist(jnp.zeros((BLOCK_N, 4)), jnp.zeros((3, 5)))
