"""L2 graph correctness: jitted model graphs vs NumPy/SciPy oracles."""

import numpy as np
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose
from scipy.interpolate import CubicSpline

import jax.numpy as jnp

from compile import model
from compile.kernels.ref import (
    ref_eval_bicubic_at,
    ref_fit_bicubic,
    ref_kmeans_step,
    ref_natural_spline_m,
    ref_pairwise_sqdist,
    ref_spline_coeffs_1d,
)


def _knots(rng, n):
    """Strictly increasing knot vector with spacing in [0.5, 2]."""
    steps = rng.uniform(0.5, 2.0, size=n - 1)
    return np.concatenate([[1.0], 1.0 + np.cumsum(steps)]).astype(np.float32)


# ---------------------------------------------------------------------------
# 1D spline machinery
# ---------------------------------------------------------------------------
class TestNaturalSplineM:
    @settings(max_examples=25, deadline=None)
    @given(
        n=st.integers(3, 12),
        b=st.integers(1, 5),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_reference(self, n, b, seed):
        rng = np.random.default_rng(seed)
        xs = _knots(rng, n)
        ys = rng.normal(size=(b, n)).astype(np.float32)
        got = np.asarray(model.natural_spline_m(jnp.asarray(xs), jnp.asarray(ys)))
        want = ref_natural_spline_m(xs, ys)
        assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_natural_boundary(self):
        rng = np.random.default_rng(1)
        xs = _knots(rng, 8)
        ys = rng.normal(size=(3, 8)).astype(np.float32)
        m = np.asarray(model.natural_spline_m(jnp.asarray(xs), jnp.asarray(ys)))
        assert_allclose(m[:, 0], 0.0)
        assert_allclose(m[:, -1], 0.0)

    def test_straight_line_has_zero_curvature(self):
        xs = np.array([0.0, 1.0, 3.0, 4.0], dtype=np.float32)
        ys = (2.0 * xs + 1.0)[None, :]
        m = np.asarray(model.natural_spline_m(jnp.asarray(xs), jnp.asarray(ys)))
        assert_allclose(m, 0.0, atol=1e-5)

    def test_matches_scipy(self):
        rng = np.random.default_rng(7)
        xs = _knots(rng, 9).astype(np.float64)
        ys = rng.normal(size=9)
        cs = CubicSpline(xs, ys, bc_type="natural")
        m_scipy = cs(xs, 2)  # second derivative at knots
        m_got = np.asarray(
            model.natural_spline_m(
                jnp.asarray(xs, jnp.float32), jnp.asarray(ys[None, :], jnp.float32)
            )
        )[0]
        assert_allclose(m_got, m_scipy, rtol=1e-3, atol=1e-3)


class TestSplineCoeffs1D:
    @settings(max_examples=25, deadline=None)
    @given(n=st.integers(3, 10), seed=st.integers(0, 2**31 - 1))
    def test_matches_reference(self, n, seed):
        rng = np.random.default_rng(seed)
        xs = _knots(rng, n)
        ys = rng.normal(size=(2, n)).astype(np.float32)
        got = np.asarray(model.spline_coeffs_1d(jnp.asarray(xs), jnp.asarray(ys)))
        want = ref_spline_coeffs_1d(xs, ys)
        assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    def test_interpolates_knots(self):
        rng = np.random.default_rng(3)
        xs = _knots(rng, 7)
        ys = rng.normal(size=(1, 7)).astype(np.float32)
        c = np.asarray(model.spline_coeffs_1d(jnp.asarray(xs), jnp.asarray(ys)))[0]
        # left endpoint of every interval: u=0 -> c0
        assert_allclose(c[:, 0], ys[0, :-1], rtol=1e-5)
        # right endpoint: u=1 -> c0+c1+c2+c3
        assert_allclose(c.sum(axis=1), ys[0, 1:], rtol=1e-3, atol=1e-4)

    def test_matches_scipy_between_knots(self):
        rng = np.random.default_rng(11)
        xs = _knots(rng, 8).astype(np.float64)
        ys = rng.normal(size=8)
        cs = CubicSpline(xs, ys, bc_type="natural")
        c = np.asarray(
            model.spline_coeffs_1d(
                jnp.asarray(xs, jnp.float32), jnp.asarray(ys[None, :], jnp.float32)
            )
        )[0]
        for i in range(7):
            for u in (0.25, 0.5, 0.75):
                x = xs[i] + u * (xs[i + 1] - xs[i])
                val = c[i, 0] + c[i, 1] * u + c[i, 2] * u**2 + c[i, 3] * u**3
                assert_allclose(val, cs(x), rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Bicubic fit
# ---------------------------------------------------------------------------
class TestFitBicubic:
    @settings(max_examples=15, deadline=None)
    @given(
        s=st.integers(1, 3),
        gp=st.integers(3, 8),
        gc=st.integers(3, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_reference(self, s, gp, gc, seed):
        rng = np.random.default_rng(seed)
        xs, ys = _knots(rng, gp), _knots(rng, gc)
        v = rng.normal(size=(s, gp, gc)).astype(np.float32)
        got = np.asarray(
            model.fit_bicubic(jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(v))
        )
        want = ref_fit_bicubic(xs, ys, v)
        assert got.shape == (s, gp - 1, gc - 1, 16)
        assert_allclose(got, want, rtol=1e-3, atol=1e-3)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_interpolates_knot_grid(self, seed):
        rng = np.random.default_rng(seed)
        gp, gc = 6, 5
        xs, ys = _knots(rng, gp), _knots(rng, gc)
        v = rng.normal(size=(2, gp, gc)).astype(np.float32)
        coeffs = np.asarray(
            model.fit_bicubic(jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(v))
        )
        for i in range(gp):
            for j in range(gc):
                got = ref_eval_bicubic_at(xs, ys, coeffs, float(xs[i]), float(ys[j]))
                assert_allclose(got, v[:, i, j], rtol=2e-3, atol=2e-3)

    def test_separable_product_surface(self):
        # f(p, cc) = p * cc is exactly representable (bilinear) and must
        # be reproduced everywhere, not just at knots.
        xs = np.array([1.0, 2.0, 4.0, 8.0], dtype=np.float32)
        ys = np.array([1.0, 3.0, 5.0], dtype=np.float32)
        v = (xs[:, None] * ys[None, :])[None].astype(np.float32)
        coeffs = np.asarray(
            model.fit_bicubic(jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(v))
        )
        for p in np.linspace(1.0, 8.0, 13):
            for cc in np.linspace(1.0, 5.0, 9):
                got = ref_eval_bicubic_at(xs, ys, coeffs, float(p), float(cc))
                assert_allclose(got[0], p * cc, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# surface_pipeline
# ---------------------------------------------------------------------------
class TestSurfacePipeline:
    def _run(self, seed=0, s=3, gp=6, gc=6, rf=4):
        rng = np.random.default_rng(seed)
        xs, ys = _knots(rng, gp), _knots(rng, gc)
        v = rng.uniform(1.0, 10.0, size=(s, gp, gc)).astype(np.float32)
        out = model.surface_pipeline(
            jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(v), rf=rf
        )
        return xs, ys, v, [np.asarray(o) for o in out]

    def test_shapes(self):
        _, _, v, (coeffs, dense, maxv, argmax, mean, std) = self._run()
        s, gp, gc = v.shape
        assert coeffs.shape == (s, gp - 1, gc - 1, 16)
        assert dense.shape == (s, (gp - 1) * 4, (gc - 1) * 4)
        assert maxv.shape == (s,)
        assert argmax.shape == (s, 2)
        assert mean.shape == (s,)
        assert std.shape == (s,)

    def test_max_dominates_knots_and_dense(self):
        _, _, v, (coeffs, dense, maxv, argmax, mean, std) = self._run(seed=5)
        for si in range(v.shape[0]):
            assert maxv[si] >= v[si].max() - 1e-4
            assert maxv[si] >= dense[si].max() - 1e-4

    def test_argmax_points_at_dense_max(self):
        _, _, v, (coeffs, dense, maxv, argmax, mean, std) = self._run(seed=9)
        for si in range(v.shape[0]):
            i, j = int(argmax[si, 0]), int(argmax[si, 1])
            assert_allclose(dense[si, i, j], dense[si].max(), rtol=1e-5)

    def test_confidence_stats(self):
        _, _, v, (coeffs, dense, maxv, argmax, mean, std) = self._run(seed=2)
        assert_allclose(mean, v.reshape(v.shape[0], -1).mean(axis=1), rtol=1e-4)
        assert_allclose(std, v.reshape(v.shape[0], -1).std(axis=1), rtol=1e-3)


# ---------------------------------------------------------------------------
# kmeans_step
# ---------------------------------------------------------------------------
class TestKmeansStep:
    @settings(max_examples=15, deadline=None)
    @given(
        k=st.integers(2, 16),
        d=st.integers(2, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_reference(self, k, d, seed):
        rng = np.random.default_rng(seed)
        x = rng.normal(size=(256, d)).astype(np.float32)
        c = rng.normal(size=(k, d)).astype(np.float32)
        new_c, assign, inertia = [
            np.asarray(o) for o in model.kmeans_step(jnp.asarray(x), jnp.asarray(c))
        ]
        want_c, want_assign, want_inertia = ref_kmeans_step(x, c)
        assert_allclose(assign, want_assign)
        assert_allclose(new_c, want_c, rtol=1e-3, atol=1e-3)
        assert_allclose(inertia[0], want_inertia, rtol=1e-3)

    def test_empty_cluster_keeps_centroid(self):
        x = np.ones((128, 4), dtype=np.float32)
        c = np.stack(
            [np.ones(4, np.float32), np.full(4, 100.0, np.float32)]
        )
        new_c, assign, _ = [
            np.asarray(o) for o in model.kmeans_step(jnp.asarray(x), jnp.asarray(c))
        ]
        assert (assign == 0).all()
        assert_allclose(new_c[1], c[1])  # untouched

    def test_inertia_decreases_under_iteration(self):
        rng = np.random.default_rng(42)
        centers = rng.normal(scale=10.0, size=(4, 6))
        x = (
            centers[rng.integers(0, 4, size=512)]
            + rng.normal(scale=0.5, size=(512, 6))
        ).astype(np.float32)
        c = x[:4].copy()
        prev = np.inf
        for _ in range(5):
            c_j, _, inertia = model.kmeans_step(jnp.asarray(x), jnp.asarray(c))
            c = np.asarray(c_j)
            val = float(np.asarray(inertia)[0])
            assert val <= prev + 1e-3
            prev = val
