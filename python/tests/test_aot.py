"""AOT lowering sanity: every entry point lowers to parseable HLO text."""

import json
import os
import subprocess
import sys
import tempfile

import jax

from compile import aot


class TestLowering:
    def test_all_entry_points_lower(self):
        for name, fn, specs in aot.entry_points():
            lowered = jax.jit(fn).lower(*specs)
            text = aot.to_hlo_text(lowered)
            assert "ENTRY" in text, f"{name}: no ENTRY computation"
            assert "HloModule" in text, f"{name}: not HLO text"
            # 64-bit id regression guard: text parser reassigns ids, but the
            # interchange must be textual, never a serialized proto blob.
            assert text.isprintable() or "\n" in text

    def test_entry_point_shapes_consistent(self):
        for name, fn, specs in aot.entry_points():
            out = jax.eval_shape(fn, *specs)
            if not isinstance(out, (tuple, list)):
                out = (out,)
            for aval in out:
                assert all(dim > 0 for dim in aval.shape), f"{name}: bad {aval.shape}"


class TestAotCli:
    def test_writes_artifacts_and_manifest(self, tmp_path):
        env = dict(os.environ)
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out", str(tmp_path)],
            check=True,
            cwd=os.path.join(os.path.dirname(__file__), ".."),
            env=env,
        )
        manifest = json.loads((tmp_path / "manifest.json").read_text())
        assert manifest["format"] == "hlo-text"
        for name, meta in manifest["artifacts"].items():
            f = tmp_path / meta["file"]
            assert f.exists(), f"{name} artifact missing"
            assert f.stat().st_size > 100
            assert meta["inputs"] and meta["outputs"]
