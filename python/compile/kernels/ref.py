"""Pure-jnp / NumPy oracles for the Pallas kernels and the L2 fit.

Everything here is written for clarity, not speed: the pytest suite
asserts the Pallas kernels and the jitted L2 graphs against these
implementations with `assert_allclose`.
"""

from __future__ import annotations

import numpy as np

__all__ = [
    "ref_pairwise_sqdist",
    "ref_surface_eval",
    "ref_natural_spline_m",
    "ref_spline_coeffs_1d",
    "ref_fit_bicubic",
    "ref_eval_bicubic_at",
    "ref_kmeans_step",
]


def ref_pairwise_sqdist(x: np.ndarray, c: np.ndarray) -> np.ndarray:
    """Naive [N, K] squared distances."""
    diff = x[:, None, :] - c[None, :, :]
    return np.maximum((diff**2).sum(axis=2), 0.0)


def ref_surface_eval(coeffs: np.ndarray, rf: int) -> np.ndarray:
    """Scalar-loop dense evaluation matching kernels.spline_eval."""
    s, gp1, gc1, _ = coeffs.shape
    out = np.zeros((s, gp1 * rf, gc1 * rf), dtype=np.float64)
    for si in range(s):
        for i in range(gp1):
            for j in range(gc1):
                cc = coeffs[si, i, j]
                for qi in range(rf):
                    u = qi / rf
                    for qj in range(rf):
                        v = qj / rf
                        acc = 0.0
                        for a in range(4):
                            for b in range(4):
                                acc += cc[4 * a + b] * u**a * v**b
                        out[si, i * rf + qi, j * rf + qj] = acc
    return out


def ref_natural_spline_m(xs: np.ndarray, ys: np.ndarray) -> np.ndarray:
    """Second derivatives M of the natural cubic spline through (xs, ys).

    ys may be [N] or [..., N] (batched along leading axes).
    """
    xs = np.asarray(xs, dtype=np.float64)
    ys = np.asarray(ys, dtype=np.float64)
    n = xs.shape[0]
    h = np.diff(xs)  # [n-1]
    batch = ys.shape[:-1]
    ys2 = ys.reshape(-1, n)
    m = np.zeros_like(ys2)
    if n > 2:
        # tridiagonal system for M[1..n-2]
        a = h[:-1] / 6.0                      # sub-diagonal
        b = (h[:-1] + h[1:]) / 3.0            # diagonal
        c = h[1:] / 6.0                       # super-diagonal
        rhs = (ys2[:, 2:] - ys2[:, 1:-1]) / h[1:] - (
            ys2[:, 1:-1] - ys2[:, :-2]
        ) / h[:-1]
        # Thomas solve per batch row
        k = n - 2
        for r in range(ys2.shape[0]):
            cp = np.zeros(k)
            dp = np.zeros(k)
            cp[0] = c[0] / b[0]
            dp[0] = rhs[r, 0] / b[0]
            for i in range(1, k):
                denom = b[i] - a[i] * cp[i - 1]
                cp[i] = c[i] / denom if i < k - 1 else 0.0
                dp[i] = (rhs[r, i] - a[i] * dp[i - 1]) / denom
            sol = np.zeros(k)
            sol[-1] = dp[-1]
            for i in range(k - 2, -1, -1):
                sol[i] = dp[i] - cp[i] * sol[i + 1]
            m[r, 1:-1] = sol
    return m.reshape(*batch, n)


def ref_spline_coeffs_1d(xs: np.ndarray, ys: np.ndarray) -> np.ndarray:
    """Per-interval cubic coefficients in *normalized* local coordinates.

    Returns coeffs [..., N-1, 4] with
        g_i(u) = c0 + c1*u + c2*u^2 + c3*u^3,   u = (x - xs[i]) / h_i.
    """
    xs = np.asarray(xs, dtype=np.float64)
    ys = np.asarray(ys, dtype=np.float64)
    m = ref_natural_spline_m(xs, ys)
    h = np.diff(xs)
    yi = ys[..., :-1]
    yi1 = ys[..., 1:]
    mi = m[..., :-1]
    mi1 = m[..., 1:]
    # unnormalized: a0 + a1 t + a2 t^2 + a3 t^3, t = x - xs[i]
    a0 = yi
    a1 = (yi1 - yi) / h - h * (2.0 * mi + mi1) / 6.0
    a2 = mi / 2.0
    a3 = (mi1 - mi) / (6.0 * h)
    # normalize: u = t / h  =>  c_k = a_k * h^k
    return np.stack([a0, a1 * h, a2 * h**2, a3 * h**3], axis=-1)


def ref_fit_bicubic(xs: np.ndarray, ys: np.ndarray, values: np.ndarray) -> np.ndarray:
    """Tensor-product natural bicubic fit (spline-of-splines).

    xs [GP] knots along p (rows), ys [GC] knots along cc (columns),
    values [S, GP, GC].  Returns coeffs [S, GP-1, GC-1, 16] with
    k = 4a+b the coefficient of u^a v^b (u along p, v along cc) in
    normalized local coordinates.
    """
    values = np.asarray(values, dtype=np.float64)
    s, gp, gc = values.shape
    # 1) spline along cc for every (surface, row): [S, GP, GC-1, 4] over v
    row_coeffs = ref_spline_coeffs_1d(ys, values)
    # 2) spline along p for every (interval j, coeff b):
    #    treat row_coeffs[s, :, j, b] as samples of a function of p
    swapped = np.moveaxis(row_coeffs, 1, -1)  # [S, GC-1, 4, GP]
    col_coeffs = ref_spline_coeffs_1d(xs, swapped)  # [S, GC-1, 4, GP-1, 4]
    # rearrange to [S, GP-1, GC-1, 4(a), 4(b)]
    out = np.transpose(col_coeffs, (0, 3, 1, 4, 2))
    return out.reshape(s, gp - 1, gc - 1, 16)


def ref_eval_bicubic_at(
    xs: np.ndarray, ys: np.ndarray, coeffs: np.ndarray, p: float, cc: float
) -> np.ndarray:
    """Evaluate [S] surfaces at one (p, cc) point from patch coefficients."""
    xs = np.asarray(xs, dtype=np.float64)
    ys = np.asarray(ys, dtype=np.float64)
    i = int(np.clip(np.searchsorted(xs, p, side="right") - 1, 0, len(xs) - 2))
    j = int(np.clip(np.searchsorted(ys, cc, side="right") - 1, 0, len(ys) - 2))
    u = (p - xs[i]) / (xs[i + 1] - xs[i])
    v = (cc - ys[j]) / (ys[j + 1] - ys[j])
    c = coeffs[:, i, j, :]  # [S, 16]
    acc = np.zeros(coeffs.shape[0])
    for a in range(4):
        for b in range(4):
            acc += c[:, 4 * a + b] * u**a * v**b
    return acc


def ref_kmeans_step(x: np.ndarray, c: np.ndarray):
    """One Lloyd iteration: (new_centroids, assignments, inertia).

    Empty clusters keep their previous centroid (matching L2 semantics).
    """
    d = ref_pairwise_sqdist(x, c)
    assign = d.argmin(axis=1)
    inertia = d[np.arange(x.shape[0]), assign].sum()
    new_c = c.copy().astype(np.float64)
    for k in range(c.shape[0]):
        mask = assign == k
        if mask.any():
            new_c[k] = x[mask].mean(axis=0)
    return new_c, assign, inertia
