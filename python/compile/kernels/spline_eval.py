"""L1 Pallas kernel: dense evaluation of batched bicubic spline surfaces.

The offline phase of the two-phase model (Nine & Kosar 2018) needs every
throughput surface evaluated on a *fine* (p, cc) grid: the Hessian maxima
test, the sampling-region score (Eq 17-19) and the Fig-4b accuracy bench
all consume dense evaluations of many surfaces at once.  That dense
refinement is the compute hot-spot, so it lives here as a Pallas kernel.

Representation
--------------
A surface is a (GP-1) x (GC-1) grid of bicubic patches.  Patch (i, j)
stores 16 coefficients c[k], k = 4*a + b, for the polynomial

    f(u, v) = sum_{a,b in 0..3} c[4a+b] * u^a * v^b

in *normalized local coordinates* u, v in [0, 1) (the fit in
`compile.model` folds the knot spacings h into the coefficients).  Using
normalized coordinates lets every patch share one precomputed Vandermonde
matrix V[RF*RF, 16] over the refinement offsets, turning the whole
evaluation into an MXU-shaped contraction

    dense_patch[RF*RF, GC-1] = V[RF*RF, 16] @ coeffs_row[GC-1, 16].T

instead of scalar Horner loops — this is the TPU adaptation called out in
DESIGN.md: the refinement work is expressed as a matmul so the MXU (not
the VPU) does it, and BlockSpec streams one (surface, patch-row) block
through VMEM at a time.

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; real-TPU perf is estimated analytically in DESIGN.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["refinement_vandermonde", "surface_eval"]


def refinement_vandermonde(rf: int, dtype=jnp.float32) -> jax.Array:
    """V[rf*rf, 16] with V[qi*rf + qj, 4a+b] = (qi/rf)^a * (qj/rf)^b.

    Row q enumerates the rf x rf refinement offsets of one patch in
    row-major order; column k = 4a+b matches the coefficient layout of
    `compile.model.fit_bicubic`.
    """
    u = jnp.arange(rf, dtype=dtype) / rf  # left-closed sample points
    pows = jnp.stack([u**0, u, u**2, u**3], axis=1)  # [rf, 4]
    # outer product over (qi, a) x (qj, b) -> [rf, rf, 4, 4]
    v4 = pows[:, None, :, None] * pows[None, :, None, :]
    return v4.reshape(rf * rf, 16)


def _eval_kernel(coeffs_ref, vand_ref, out_ref, *, rf: int, gc1: int):
    """One program instance: one (surface, patch-row) block.

    coeffs_ref : [1, 1, gc1, 16]  patch coefficients of this row
    vand_ref   : [rf*rf, 16]      shared Vandermonde matrix
    out_ref    : [1, rf, gc1*rf]  dense evaluation of the row
    """
    coeffs = coeffs_ref[0, 0]                       # [gc1, 16]
    vand = vand_ref[...]                            # [rf*rf, 16]
    # MXU contraction: all refinement points of all patches in the row.
    dense = jnp.dot(
        vand, coeffs.T, preferred_element_type=jnp.float32
    )                                               # [rf*rf, gc1]
    # (qi, qj, j) -> (qi, j, qj): row-major within each patch row.
    dense = dense.reshape(rf, rf, gc1).transpose(0, 2, 1)
    out_ref[0] = dense.reshape(rf, gc1 * rf)


@functools.partial(jax.jit, static_argnames=("rf",))
def surface_eval(coeffs: jax.Array, rf: int = 8) -> jax.Array:
    """Densely evaluate batched bicubic surfaces.

    Parameters
    ----------
    coeffs : [S, GP-1, GC-1, 16] float32
        Per-patch polynomial coefficients in normalized local coordinates.
    rf : int
        Refinement factor: each patch contributes an rf x rf tile.

    Returns
    -------
    dense : [S, (GP-1)*rf, (GC-1)*rf] float32
        dense[s, i*rf + qi, j*rf + qj] = f_s,patch(i,j)(qi/rf, qj/rf)
    """
    s, gp1, gc1, ncoef = coeffs.shape
    assert ncoef == 16, f"expected 16 bicubic coefficients, got {ncoef}"
    vand = refinement_vandermonde(rf, coeffs.dtype)

    kernel = functools.partial(_eval_kernel, rf=rf, gc1=gc1)
    return pl.pallas_call(
        kernel,
        grid=(s, gp1),
        in_specs=[
            pl.BlockSpec((1, 1, gc1, 16), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((rf * rf, 16), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, rf, gc1 * rf), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((s, gp1 * rf, gc1 * rf), coeffs.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(coeffs, vand)
