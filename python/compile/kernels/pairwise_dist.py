"""L1 Pallas kernel: pairwise squared Euclidean distances for K-means++.

The offline clustering phase assigns every historical-log feature vector
to its nearest centroid each Lloyd iteration; with six weeks of logs the
[N, K] distance matrix is the dominant cost.  The kernel uses the
classic expansion

    ||x - c||^2 = ||x||^2 + ||c||^2 - 2 <x, c>

so the cross term is a single [BN, D] @ [D, K] matmul per tile — again
MXU-shaped (DESIGN.md hardware-adaptation note).  N is tiled with
BlockSpec; the full centroid block rides along in VMEM (K and D are
small: K <= 16, D <= 8 after padding).

interpret=True: CPU PJRT cannot execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["pairwise_sqdist", "BLOCK_N"]

BLOCK_N = 128  # rows of X per program instance


def _dist_kernel(x_ref, c_ref, out_ref):
    """out[bn, k] = ||x_bn||^2 + ||c_k||^2 - 2 x_bn . c_k (clamped at 0)."""
    x = x_ref[...]                                   # [BN, D]
    c = c_ref[...]                                   # [K, D]
    x2 = jnp.sum(x * x, axis=1, keepdims=True)       # [BN, 1]
    c2 = jnp.sum(c * c, axis=1)[None, :]             # [1, K]
    cross = jnp.dot(x, c.T, preferred_element_type=jnp.float32)
    # numerical noise can push tiny distances below zero; clamp so the
    # argmin/sqrt consumers never see negatives.
    out_ref[...] = jnp.maximum(x2 + c2 - 2.0 * cross, 0.0)


@jax.jit
def pairwise_sqdist(x: jax.Array, c: jax.Array) -> jax.Array:
    """Squared distances between rows of x [N, D] and c [K, D] -> [N, K].

    N must be a multiple of BLOCK_N (the AOT shapes guarantee it; the
    Rust caller pads with +inf-distance sentinel rows when needed).
    """
    n, d = x.shape
    k, d2 = c.shape
    assert d == d2, f"feature dims differ: {d} vs {d2}"
    assert n % BLOCK_N == 0, f"N={n} not a multiple of {BLOCK_N}"

    return pl.pallas_call(
        _dist_kernel,
        grid=(n // BLOCK_N,),
        in_specs=[
            pl.BlockSpec((BLOCK_N, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N, k), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, k), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x.astype(jnp.float32), c.astype(jnp.float32))
