"""L2: JAX compute graphs for the two-phase model's offline analysis.

Three jitted entry points, each AOT-lowered to HLO text by
``compile.aot`` and executed from the Rust coordinator via PJRT:

* ``fit_bicubic``      — tensor-product natural bicubic spline fit
                         (values grid -> per-patch coefficients);
* ``surface_pipeline`` — fit + Pallas dense refinement + per-surface
                         maxima and Gaussian confidence stats, fused
                         into one graph (one host roundtrip per batch);
* ``kmeans_step``      — one Lloyd iteration on log feature vectors,
                         built on the Pallas pairwise-distance kernel.

The tridiagonal natural-spline systems are solved with a scan-based
Thomas algorithm: O(N), batched, and free of LAPACK custom-calls that
the Rust-side XLA (xla_extension 0.5.1) could not execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.pairwise_dist import pairwise_sqdist
from .kernels.spline_eval import surface_eval

__all__ = [
    "natural_spline_m",
    "spline_coeffs_1d",
    "fit_bicubic",
    "surface_pipeline",
    "kmeans_step",
]


def natural_spline_m(xs: jax.Array, ys: jax.Array) -> jax.Array:
    """Second derivatives of the natural cubic spline through (xs, ys).

    xs : [N] strictly increasing knots.
    ys : [..., N] batched values.
    Returns M : [..., N] with M[..., 0] = M[..., -1] = 0.
    """
    n = xs.shape[0]
    batch = ys.shape[:-1]
    ysf = ys.reshape(-1, n)  # [B, N]
    bsz = ysf.shape[0]

    h = jnp.diff(xs)  # [N-1]
    sub = h[:-1] / 6.0                    # [N-2]
    diag = (h[:-1] + h[1:]) / 3.0
    sup = h[1:] / 6.0
    rhs = (ysf[:, 2:] - ysf[:, 1:-1]) / h[1:] - (
        ysf[:, 1:-1] - ysf[:, :-2]
    ) / h[:-1]  # [B, N-2]

    # Thomas forward sweep.  The cp carry is scalar (matrix depends only
    # on xs); dp carries the whole batch.  Zeroing sub[0] folds the first
    # row into the same recurrence (cp_prev = dp_prev = 0 initially).
    sub0 = sub.at[0].set(0.0)
    rhs_t = jnp.moveaxis(rhs, -1, 0)  # [N-2, B]

    def fwd(carry, inp):
        cp_prev, dp_prev = carry
        a_i, b_i, c_i, r_i = inp
        denom = b_i - a_i * cp_prev
        cp = c_i / denom
        dp = (r_i - a_i * dp_prev) / denom
        return (cp, dp), (cp, dp)

    init = (jnp.zeros((), ysf.dtype), jnp.zeros((bsz,), ysf.dtype))
    _, (cps, dps) = lax.scan(fwd, init, (sub0, diag, sup, rhs_t))

    def bwd(sol_next, inp):
        cp, dp = inp
        sol = dp - cp * sol_next
        return sol, sol

    _, sols = lax.scan(bwd, jnp.zeros((bsz,), ysf.dtype), (cps, dps), reverse=True)
    m_inner = jnp.moveaxis(sols, 0, -1)  # [B, N-2]
    m = jnp.pad(m_inner, ((0, 0), (1, 1)))
    return m.reshape(*batch, n)


def spline_coeffs_1d(xs: jax.Array, ys: jax.Array) -> jax.Array:
    """Per-interval cubic coefficients, normalized local coordinates.

    Returns [..., N-1, 4]: g_i(u) = c0 + c1 u + c2 u^2 + c3 u^3 with
    u = (x - xs[i]) / h_i.  Mirrors ``kernels.ref.ref_spline_coeffs_1d``.
    """
    m = natural_spline_m(xs, ys)
    h = jnp.diff(xs)
    yi, yi1 = ys[..., :-1], ys[..., 1:]
    mi, mi1 = m[..., :-1], m[..., 1:]
    a0 = yi
    a1 = (yi1 - yi) / h - h * (2.0 * mi + mi1) / 6.0
    a2 = mi / 2.0
    a3 = (mi1 - mi) / (6.0 * h)
    return jnp.stack([a0, a1 * h, a2 * h**2, a3 * h**3], axis=-1)


@jax.jit
def fit_bicubic(xs: jax.Array, ys: jax.Array, values: jax.Array) -> jax.Array:
    """Tensor-product natural bicubic fit.

    xs [GP] (p knots), ys [GC] (cc knots), values [S, GP, GC].
    Returns coeffs [S, GP-1, GC-1, 16]; k = 4a+b indexes u^a v^b.
    """
    s, gp, gc = values.shape
    row = spline_coeffs_1d(ys, values)            # [S, GP, GC-1, 4] (over v)
    swapped = jnp.moveaxis(row, 1, -1)            # [S, GC-1, 4, GP]
    col = spline_coeffs_1d(xs, swapped)           # [S, GC-1, 4, GP-1, 4]
    out = jnp.transpose(col, (0, 3, 1, 4, 2))     # [S, GP-1, GC-1, 4a, 4b]
    return out.reshape(s, gp - 1, gc - 1, 16)


@functools.partial(jax.jit, static_argnames=("rf",))
def surface_pipeline(
    xs: jax.Array, ys: jax.Array, values: jax.Array, rf: int = 8
):
    """Fit + dense refinement + maxima + confidence stats, one graph.

    Returns (coeffs, dense, maxv, argmax_ij, mean, std):
      coeffs    [S, GP-1, GC-1, 16]
      dense     [S, (GP-1)*rf, (GC-1)*rf]   (Pallas kernel)
      maxv      [S]    max over dense refinement and the knot grid
      argmax_ij [S, 2] refined-grid coordinates of the max (f32)
      mean/std  [S]    Gaussian confidence stats over the knot values
    """
    s, gp, gc = values.shape
    coeffs = fit_bicubic(xs, ys, values)
    dense = surface_eval(coeffs, rf=rf)           # [S, (GP-1)rf, (GC-1)rf]

    flat = dense.reshape(s, -1)
    dense_max = jnp.max(flat, axis=1)
    dense_arg = jnp.argmax(flat, axis=1)
    w = dense.shape[2]
    arg_i = (dense_arg // w).astype(jnp.float32)
    arg_j = (dense_arg % w).astype(jnp.float32)

    # the left-closed refinement never samples the far knot row/column;
    # fold the raw knot values in so a boundary max is never missed.
    knot_max = jnp.max(values.reshape(s, -1), axis=1)
    maxv = jnp.maximum(dense_max, knot_max)

    mean = jnp.mean(values.reshape(s, -1), axis=1)
    std = jnp.std(values.reshape(s, -1), axis=1)
    argmax_ij = jnp.stack([arg_i, arg_j], axis=1)
    return coeffs, dense, maxv, argmax_ij, mean, std


@jax.jit
def kmeans_step(x: jax.Array, c: jax.Array):
    """One Lloyd iteration.

    x [N, D] points, c [K, D] centroids.
    Returns (new_c [K, D], assign [N] f32, inertia [1]).
    Empty clusters keep their previous centroid.
    """
    d = pairwise_sqdist(x, c)                     # [N, K] (Pallas)
    assign = jnp.argmin(d, axis=1)                # [N]
    k = c.shape[0]
    onehot = jax.nn.one_hot(assign, k, dtype=x.dtype)  # [N, K]
    counts = jnp.sum(onehot, axis=0)              # [K]
    sums = jnp.dot(onehot.T, x)                   # [K, D]
    new_c = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts, 1.0)[:, None], c)
    inertia = jnp.sum(jnp.min(d, axis=1), keepdims=True)
    return new_c, assign.astype(jnp.float32), inertia
