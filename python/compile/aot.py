"""AOT compile path: lower the L2 graphs to HLO *text* artifacts.

Usage (from python/):  python -m compile.aot --out ../artifacts

Emits one ``<name>.hlo.txt`` per entry point plus ``manifest.json``
describing shapes, so the Rust runtime can validate its buffers before
executing.  HLO text — NOT ``lowered.compile()`` / ``.serialize()`` —
is the interchange format: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the published
``xla`` 0.1.6 crate binds) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Python runs ONCE, at build time.  The Rust binary never imports it.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# ---------------------------------------------------------------------------
# Fixed AOT shape family (the Rust side pads to these; see runtime/manifest.rs)
# ---------------------------------------------------------------------------
S = 16     # surfaces per batch (cluster x load-bucket slices)
GP = 8     # knots along p  (parallelism axis)
GC = 8     # knots along cc (concurrency axis)
RF = 8     # per-patch refinement factor
N = 2048   # log feature vectors per kmeans batch
D = 8      # padded feature dimension
K = 16     # max clusters

F32 = jnp.float32


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def entry_points():
    """(name, fn, example_args) for every artifact."""
    return [
        (
            "surface_fit",
            model.fit_bicubic,
            (_spec(GP), _spec(GC), _spec(S, GP, GC)),
        ),
        (
            "surface_pipeline",
            lambda xs, ys, v: model.surface_pipeline(xs, ys, v, rf=RF),
            (_spec(GP), _spec(GC), _spec(S, GP, GC)),
        ),
        (
            "kmeans_step",
            model.kmeans_step,
            (_spec(N, D), _spec(K, D)),
        ),
    ]


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_list(avals) -> list:
    return [list(a.shape) for a in avals]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = {
        "format": "hlo-text",
        "consts": {"S": S, "GP": GP, "GC": GC, "RF": RF, "N": N, "D": D, "K": K},
        "artifacts": {},
    }
    for name, fn, specs in entry_points():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        # output shapes from an abstract eval of the jitted fn
        out_avals = jax.eval_shape(fn, *specs)
        if not isinstance(out_avals, (tuple, list)):
            out_avals = (out_avals,)
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "inputs": shape_list(specs),
            "outputs": shape_list(out_avals),
        }
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
