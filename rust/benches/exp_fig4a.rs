//! Regenerates the paper's fig4a (see DESIGN.md §5). `harness = false`:
//! the in-tree timer harness replaces criterion (offline registry).

fn main() {
    let (_, elapsed) = twophase::util::timer::time_once(|| {
        twophase::experiments::fig4a::run()
    });
    println!("[bench] exp_fig4a completed in {elapsed:?}");
}
