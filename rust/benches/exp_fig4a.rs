//! Regenerates the paper's fig4a (see DESIGN.md §5). `harness = false`:
//! the in-tree timer harness replaces criterion (offline registry).
//! Times the per-cell forked-seed sweep serial (`PALLAS_THREADS=1`) vs
//! parallel and asserts the two runs are bit-identical.

use twophase::util::par;
use twophase::util::timer::time_once;

fn main() {
    let orig_threads = std::env::var("PALLAS_THREADS").ok();
    std::env::set_var("PALLAS_THREADS", "1");
    let (serial, t_serial) = time_once(|| twophase::experiments::fig4a::run());
    match &orig_threads {
        Some(v) => std::env::set_var("PALLAS_THREADS", v),
        None => std::env::remove_var("PALLAS_THREADS"),
    }
    let threads = par::max_threads();
    let (parallel, elapsed) = time_once(|| twophase::experiments::fig4a::run());

    assert_eq!(
        serial.mean.to_bits(),
        parallel.mean.to_bits(),
        "parallel fig4a sweep must be bit-identical to serial"
    );
    assert_eq!(serial.sigma.to_bits(), parallel.sigma.to_bits());
    for (a, b) in serial.cell_means.iter().zip(&parallel.cell_means) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    println!(
        "[bench] exp_fig4a completed in {elapsed:?} \
         (serial {t_serial:?} vs {threads} threads, outputs bit-identical)"
    );
}
