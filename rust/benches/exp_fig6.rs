//! Regenerates the paper's fig6 (see DESIGN.md §5). `harness = false`:
//! the in-tree timer harness replaces criterion (offline registry).

fn main() {
    let (_, elapsed) = twophase::util::timer::time_once(|| {
        twophase::experiments::fig6::run()
    });
    println!("[bench] exp_fig6 completed in {elapsed:?}");
}
