//! Regenerates the paper's fig8 (see DESIGN.md §5). `harness = false`:
//! the in-tree timer harness replaces criterion (offline registry).

fn main() {
    let (_, elapsed) = twophase::util::timer::time_once(|| {
        twophase::experiments::fig8::run()
    });
    println!("[bench] exp_fig8 completed in {elapsed:?}");
}
