//! Parallelism + caching bench: times the offline knowledge-base build
//! serial (`PALLAS_THREADS=1`) vs parallel, proves the two builds are
//! bit-identical via `KnowledgeBase::digest`, then measures the
//! historical tuning cache's hit rate on a repeat workload.  Writes
//! `BENCH_parallel.json` (parsed by the CI bench-smoke step).
//! `harness = false`.

use std::sync::Arc;

use twophase::baselines::ann_ot::AnnOtModel;
use twophase::baselines::api::OptimizerKind;
use twophase::baselines::static_ann::StaticAnnModel;
use twophase::coordinator::orchestrator::{Orchestrator, OrchestratorConfig, TransferRequest};
use twophase::logs::generator::{generate_history, GeneratorConfig};
use twophase::offline::pipeline::{KnowledgeBase, OfflineConfig};
use twophase::sim::dataset::Dataset;
use twophase::sim::profile::NetProfile;
use twophase::util::json::Value;
use twophase::util::par;
use twophase::util::timer::time_once;

fn env_or<T: std::str::FromStr>(name: &str, default: T) -> T {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn main() {
    let days: f64 = env_or("TWOPHASE_DAYS", 7.0);
    let reps: usize = env_or("TWOPHASE_REPS", 3);
    let profile = NetProfile::xsede();
    let logs = generate_history(
        &profile,
        &GeneratorConfig {
            days,
            transfers_per_hour: 8.0,
            seed: 42,
        },
    );

    // --- serial vs parallel knowledge-base build ----------------------
    let orig_threads = std::env::var("PALLAS_THREADS").ok();
    std::env::set_var("PALLAS_THREADS", "1");
    let (kb_serial, t_serial) =
        time_once(|| KnowledgeBase::build_native(logs.clone(), OfflineConfig::default()));
    match &orig_threads {
        Some(v) => std::env::set_var("PALLAS_THREADS", v),
        None => std::env::remove_var("PALLAS_THREADS"),
    }
    let threads = par::max_threads();
    let (kb_par, t_par) =
        time_once(|| KnowledgeBase::build_native(logs.clone(), OfflineConfig::default()));

    let digest_serial = kb_serial.digest();
    let digest_par = kb_par.digest();
    assert_eq!(
        digest_serial, digest_par,
        "parallel knowledge-base build must be bit-identical to serial"
    );
    let speedup = t_serial.as_secs_f64() / t_par.as_secs_f64().max(1e-9);
    println!(
        "[bench] kb build ({days} days): serial {t_serial:?} vs {threads} threads \
         {t_par:?} ({speedup:.2}x, digests agree)"
    );

    // --- tuning-cache hit rate on a repeat workload -------------------
    // round 0 is all cold (distinct fingerprints via distinct file
    // counts); round 1 replays the same requests and must warm-start
    let sp = Arc::new(StaticAnnModel::train(&logs, 32, 0xE1));
    let annot = Arc::new(AnnOtModel::train(&logs, 32, 0xE2));
    let orch = Orchestrator::new(
        Arc::new(kb_par),
        sp,
        annot,
        OrchestratorConfig {
            cache_capacity: 16,
            ..OrchestratorConfig::default()
        },
    )
    .expect("bench corpus yields a non-empty knowledge base");
    let tracer = Arc::new(twophase::util::trace::Tracer::new());
    orch.set_tracer(Some(Arc::clone(&tracer)));
    let mut warm_samples = 0usize;
    for round in 0..2usize {
        for rep in 0..reps {
            let req = TransferRequest {
                id: (round * reps + rep) as u64 + 1,
                profile: profile.clone(),
                dataset: Dataset::new(64 << rep.min(8), 512.0),
                model: OptimizerKind::Asm,
                seed: 7 + rep as u64,
                phase_s: 3.0 * 3600.0,
            };
            let report = orch.execute(&req);
            if round == 1 {
                warm_samples += report.sample_transfers;
            }
        }
    }
    orch.set_tracer(None);
    let stats = orch.cache_stats();
    println!(
        "[bench] tuning cache over {} transfers: {} hits / {} misses \
         (hit rate {:.0}%, {warm_samples} sample transfers on the warm round)",
        2 * reps,
        stats.hits,
        stats.misses,
        stats.hit_rate() * 100.0
    );
    let m = tracer.metrics();
    assert_eq!(
        m.counter("cache.hits"),
        stats.hits,
        "trace cache counters must agree with the cache's own stats"
    );
    assert_eq!(m.counter("cache.misses"), stats.misses);
    println!("[bench] {}", tracer.summary());

    let out = Value::obj(vec![
        ("bench", Value::str("exp_parallel")),
        ("days", Value::Num(days)),
        ("reps", Value::Num(reps as f64)),
        ("threads", Value::Num(threads as f64)),
        ("build_serial_s", Value::Num(t_serial.as_secs_f64())),
        ("build_parallel_s", Value::Num(t_par.as_secs_f64())),
        ("speedup", Value::Num(speedup)),
        ("digest_match", Value::Bool(digest_serial == digest_par)),
        (
            "cache",
            Value::obj(vec![
                ("hits", Value::Num(stats.hits as f64)),
                ("misses", Value::Num(stats.misses as f64)),
                ("insertions", Value::Num(stats.insertions as f64)),
                ("evictions", Value::Num(stats.evictions as f64)),
                ("hit_rate", Value::Num(stats.hit_rate())),
                ("warm_round_samples", Value::Num(warm_samples as f64)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_parallel.json", format!("{out}\n"))
        .expect("write BENCH_parallel.json");
    println!("[bench] exp_parallel wrote BENCH_parallel.json");
}
