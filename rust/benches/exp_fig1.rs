//! Regenerates the paper's fig1 (see DESIGN.md §5). `harness = false`:
//! the in-tree timer harness replaces criterion (offline registry).

fn main() {
    let (_, elapsed) = twophase::util::timer::time_once(|| {
        twophase::experiments::fig1::run()
    });
    println!("[bench] exp_fig1 completed in {elapsed:?}");
}
