//! Regenerates the paper's table1 (see DESIGN.md §5). `harness = false`:
//! the in-tree timer harness replaces criterion (offline registry).

fn main() {
    let (_, elapsed) = twophase::util::timer::time_once(|| {
        twophase::experiments::table1::run()
    });
    println!("[bench] exp_table1 completed in {elapsed:?}");
}
