//! Regenerates the fault-injection robustness sweep: recovered
//! throughput fraction vs fault intensity, two-phase (ASM) against the
//! GO/SC/HARP static baselines.  `harness = false`.

fn main() {
    let (res, elapsed) = twophase::util::timer::time_once(|| {
        twophase::experiments::robustness::run()
    });
    let levels = twophase::experiments::robustness::INTENSITIES.len();
    println!(
        "[bench] exp_robustness completed in {elapsed:?} (ASM wins {}/{} levels)",
        res.asm_win_levels(),
        levels
    );
}
