//! Regenerates the fault-injection robustness sweep: recovered
//! throughput fraction vs fault intensity, two-phase (ASM) against the
//! GO/SC/HARP static baselines.  Attaches a deterministic trace
//! collector to the shared orchestrator and prints its summary plus
//! the recovery-path counters it gathered.  `harness = false`.

use std::sync::Arc;

use twophase::experiments::common::ctx;
use twophase::util::trace::Tracer;

fn main() {
    let tracer = Arc::new(Tracer::new());
    ctx().orchestrator.set_tracer(Some(Arc::clone(&tracer)));
    let (res, elapsed) = twophase::util::timer::time_once(|| {
        twophase::experiments::robustness::run()
    });
    ctx().orchestrator.set_tracer(None);
    let levels = twophase::experiments::robustness::INTENSITIES.len();
    println!(
        "[bench] exp_robustness completed in {elapsed:?} (ASM wins {}/{} levels)",
        res.asm_win_levels(),
        levels
    );
    let m = tracer.metrics();
    println!(
        "[bench] {}; chunks={} stalls={} retries={} resumed={} requeries={} fault-transitions={}",
        tracer.summary(),
        m.counter("chunks"),
        m.counter("chunk.stalls"),
        m.counter("retries"),
        m.counter("chunks.resumed"),
        m.counter("asm.requeries"),
        m.counter("fault.transitions"),
    );
}
