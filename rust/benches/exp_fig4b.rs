//! Regenerates the paper's fig4b (see DESIGN.md §5). `harness = false`:
//! the in-tree timer harness replaces criterion (offline registry).

fn main() {
    let (_, elapsed) = twophase::util::timer::time_once(|| {
        twophase::experiments::fig4b::run()
    });
    println!("[bench] exp_fig4b completed in {elapsed:?}");
}
