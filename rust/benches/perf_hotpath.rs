//! §Perf microbenches: the hot paths of each layer, timed with the
//! in-tree harness (criterion is unavailable offline — DESIGN.md §4).
//!
//! * L3 simulator tick rate and ASM decision latency (must be
//!   negligible next to a chunk transfer);
//! * native vs PJRT surface pipeline (L2+L1 through the artifacts);
//! * offline pipeline end-to-end on a six-week corpus.

use twophase::logs::generator::{generate_history, GeneratorConfig};
use twophase::offline::pipeline::{KnowledgeBase, OfflineConfig};
use twophase::offline::surface::{knot_lattice, NativeSurfaceBackend, SurfaceBackend};
use twophase::online::controller::DynamicTuner;
use twophase::runtime::accel::PjrtSurfaceBackend;
use twophase::runtime::engine::Engine;
use twophase::sim::dataset::Dataset;
use twophase::sim::profile::NetProfile;
use twophase::sim::traffic::TrafficProcess;
use twophase::sim::transfer::ThroughputModel;
use twophase::util::rng::Rng;
use twophase::util::timer::bench;
use twophase::Params;

fn main() {
    // --- L3: simulator steady-state evaluation ------------------------
    let profile = NetProfile::xsede();
    let model = ThroughputModel::new(profile.clone());
    let load = TrafficProcess::fixed(&profile, 0.3);
    let dataset = Dataset::new(256, 256.0);
    let r = bench("sim::steady (single eval)", 100, 1000, || {
        std::hint::black_box(model.steady(Params::new(8, 4, 8), &dataset, &load));
    });
    println!(
        "  -> {:.2} M evals/s",
        1e9 / r.median_ns() / 1e6
    );

    // --- L3: ASM decision latency -------------------------------------
    let logs = generate_history(
        &profile,
        &GeneratorConfig {
            days: 7.0,
            transfers_per_hour: 8.0,
            seed: 42,
        },
    );
    let kb = KnowledgeBase::build_native(logs.clone(), OfflineConfig::default());
    let set = kb
        .query(profile.rtt_s, profile.bandwidth_mbps, 256.0, 256)
        .expect("kb built")
        .clone();
    bench("online::asm decision (observe)", 100, 1000, || {
        let mut tuner = DynamicTuner::with_defaults(set.clone());
        std::hint::black_box(tuner.observe(1000.0));
    });

    // --- offline pipeline end-to-end ----------------------------------
    bench("offline::KnowledgeBase::build (7-day corpus)", 1, 5, || {
        std::hint::black_box(KnowledgeBase::build_native(
            logs.clone(),
            OfflineConfig::default(),
        ));
    });

    // --- L2+L1: surface fit+refine, native vs PJRT --------------------
    let xs = knot_lattice();
    let mut rng = Rng::new(7);
    let grids: Vec<Vec<Vec<f64>>> = (0..16)
        .map(|_| {
            (0..xs.len())
                .map(|_| (0..xs.len()).map(|_| rng.uniform(10.0, 1000.0)).collect())
                .collect()
        })
        .collect();
    bench("surface fit+refine x16 (native)", 3, 30, || {
        std::hint::black_box(NativeSurfaceBackend.fit_batch(&xs, &xs, &grids, 8));
    });
    match Engine::try_default() {
        Some(engine) => {
            let backend = PjrtSurfaceBackend::new(engine);
            bench("surface fit+refine x16 (PJRT artifacts)", 3, 30, || {
                std::hint::black_box(backend.fit_batch(&xs, &xs, &grids, 8));
            });
        }
        None => println!("(PJRT artifacts not built; skipping accelerated bench)"),
    }
}
