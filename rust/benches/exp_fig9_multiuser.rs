//! Regenerates Figures 2/9/10 + the §5.4 fairness numbers
//! (multi-user contention on Chameleon), timing the experiment's grid
//! fan-out serial (`PALLAS_THREADS=1`) vs parallel and proving the two
//! results bit-identical via `Fig9Result::digest`.  Writes
//! `BENCH_fig9.json` with the wall times and the `util::par` fan-out
//! trace counters (parsed by the CI bench-smoke step).
//! `harness = false`.

use std::sync::Arc;

use twophase::baselines::api::OptimizerKind;
use twophase::experiments::{common, fig9};
use twophase::util::json::Value;
use twophase::util::par;
use twophase::util::timer::time_once;
use twophase::util::trace::Tracer;

fn main() {
    // Warm the shared context outside the timed sections (and outside
    // any pool worker), so both runs time only the experiment fan-out
    // and the tracer's counter window sees only fig9's own par calls.
    let _ = common::ctx();

    let orig_threads = std::env::var("PALLAS_THREADS").ok();
    std::env::set_var("PALLAS_THREADS", "1");
    let (serial, t_serial) = time_once(|| fig9::run());
    match &orig_threads {
        Some(v) => std::env::set_var("PALLAS_THREADS", v),
        None => std::env::remove_var("PALLAS_THREADS"),
    }
    let threads = par::max_threads();

    let tracer = Arc::new(Tracer::new());
    let fan_before = par::fanout_stats();
    let (parallel, t_par) = time_once(|| fig9::run_traced(Some(&tracer)));
    let fan_after = par::fanout_stats();
    let metrics = tracer.metrics();

    assert_eq!(
        serial.digest(),
        parallel.digest(),
        "parallel fig9 grid must be bit-identical to serial"
    );
    let speedup = t_serial.as_secs_f64() / t_par.as_secs_f64().max(1e-9);
    println!(
        "[bench] fig9 grid ({} cells): serial {t_serial:?} vs {threads} threads \
         {t_par:?} ({speedup:.2}x, digests agree)",
        parallel.rows.len() + parallel.skipped.len()
    );

    // the tracer's exported counters and a direct counter snapshot must
    // tell the same story (CI asserts this from BENCH_fig9.json)
    let calls = metrics.counter("par.fanout_calls");
    let units = metrics.counter("par.fanout_units");
    let calls_direct = fan_after.calls - fan_before.calls;
    let units_direct = fan_after.units - fan_before.units;
    println!(
        "[bench] fan-out trace: {calls} par calls over {units} units \
         (direct snapshot: {calls_direct}/{units_direct})"
    );

    let asm = parallel.aggregate(OptimizerKind::Asm);
    let noopt = parallel.aggregate(OptimizerKind::NoOpt);
    println!(
        "[bench] exp_fig9_multiuser completed (ASM/NoOpt = {:.1}x)",
        asm / noopt.max(1e-9)
    );

    let out = Value::obj(vec![
        ("bench", Value::str("exp_fig9_multiuser")),
        ("threads", Value::Num(threads as f64)),
        ("serial_s", Value::Num(t_serial.as_secs_f64())),
        ("parallel_s", Value::Num(t_par.as_secs_f64())),
        ("speedup", Value::Num(speedup)),
        (
            "digest_match",
            Value::Bool(serial.digest() == parallel.digest()),
        ),
        ("rows", Value::Num(parallel.rows.len() as f64)),
        ("skips", Value::Num(parallel.skipped.len() as f64)),
        (
            "fanout",
            Value::obj(vec![
                ("calls", Value::Num(calls as f64)),
                ("units", Value::Num(units as f64)),
                ("calls_direct", Value::Num(calls_direct as f64)),
                ("units_direct", Value::Num(units_direct as f64)),
            ]),
        ),
    ]);
    std::fs::write("BENCH_fig9.json", format!("{out}\n")).expect("write BENCH_fig9.json");
    println!("[bench] exp_fig9_multiuser wrote BENCH_fig9.json");
}
