//! Regenerates Figures 2/9/10 + the §5.4 fairness numbers
//! (multi-user contention on Chameleon).  `harness = false`.

fn main() {
    let (res, elapsed) = twophase::util::timer::time_once(|| {
        twophase::experiments::fig9::run()
    });
    // headline guardrails printed for EXPERIMENTS.md
    let asm = res.aggregate(twophase::baselines::api::OptimizerKind::Asm);
    let noopt = res.aggregate(twophase::baselines::api::OptimizerKind::NoOpt);
    println!("[bench] exp_fig9_multiuser completed in {elapsed:?} (ASM/NoOpt = {:.1}x)", asm / noopt.max(1e-9));
}
