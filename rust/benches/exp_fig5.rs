//! Regenerates the paper's fig5 (see DESIGN.md §5). `harness = false`:
//! the in-tree timer harness replaces criterion (offline registry).

fn main() {
    let (_, elapsed) = twophase::util::timer::time_once(|| {
        twophase::experiments::fig5::run()
    });
    println!("[bench] exp_fig5 completed in {elapsed:?}");
}
