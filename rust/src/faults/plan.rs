//! Seed-driven fault schedules: Poisson event arrivals over a horizon,
//! with per-kind magnitude and duration distributions scaled by a
//! single `intensity` knob in [0, 1].

use crate::sim::profile::NetProfile;
use crate::util::rng::Rng;

/// The five supported fault kinds (module docs describe each).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    LinkDegradation,
    LossBurst,
    RttInflation,
    TrafficSurge,
    EndpointStall,
}

impl FaultKind {
    pub fn all() -> [FaultKind; 5] {
        [
            FaultKind::LinkDegradation,
            FaultKind::LossBurst,
            FaultKind::RttInflation,
            FaultKind::TrafficSurge,
            FaultKind::EndpointStall,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::LinkDegradation => "link-degradation",
            FaultKind::LossBurst => "loss-burst",
            FaultKind::RttInflation => "rtt-inflation",
            FaultKind::TrafficSurge => "traffic-surge",
            FaultKind::EndpointStall => "endpoint-stall",
        }
    }
}

/// One scheduled fault. `magnitude` semantics depend on the kind:
/// fraction of capacity removed (LinkDegradation), extra loss
/// probability (LossBurst), RTT multiplier minus one (RttInflation),
/// extra background streams (TrafficSurge); unused for EndpointStall
/// (the stall's effect is its duration).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub kind: FaultKind,
    pub t_start_s: f64,
    pub duration_s: f64,
    pub magnitude: f64,
}

impl FaultEvent {
    pub fn t_end_s(&self) -> f64 {
        self.t_start_s + self.duration_s
    }

    pub fn active_at(&self, t_s: f64) -> bool {
        t_s >= self.t_start_s && t_s < self.t_end_s()
    }
}

/// Knobs for [`FaultPlan::generate`].
#[derive(Debug, Clone)]
pub struct FaultPlanConfig {
    /// Schedule window in seconds; no event starts past it.
    pub horizon_s: f64,
    /// Mean event arrival rate (Poisson inter-arrivals).
    pub events_per_hour: f64,
    /// Severity knob in [0, 1] scaling every magnitude draw.
    pub intensity: f64,
    /// Fault kinds to draw from (uniformly). Must be non-empty.
    pub kinds: Vec<FaultKind>,
}

impl Default for FaultPlanConfig {
    fn default() -> Self {
        FaultPlanConfig {
            horizon_s: 4.0 * 3600.0,
            events_per_hour: 6.0,
            intensity: 0.5,
            kinds: FaultKind::all().to_vec(),
        }
    }
}

impl FaultPlanConfig {
    /// Default schedule at a given intensity.
    pub fn with_intensity(intensity: f64) -> FaultPlanConfig {
        FaultPlanConfig {
            intensity: intensity.clamp(0.0, 1.0),
            ..FaultPlanConfig::default()
        }
    }
}

/// A deterministic schedule of fault events, sorted by start time.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// A plan with no events (the benign network).
    pub fn empty() -> FaultPlan {
        FaultPlan { events: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Build a schedule from `seed` alone: identical seeds (and config
    /// and profile) yield identical event sequences.
    pub fn generate(profile: &NetProfile, cfg: &FaultPlanConfig, seed: u64) -> FaultPlan {
        assert!(!cfg.kinds.is_empty(), "fault plan needs at least one kind");
        let mut rng = Rng::new(seed ^ 0xFA_017_5EED);
        let rate_per_s = cfg.events_per_hour / 3600.0;
        let mag = cfg.intensity.clamp(0.0, 1.0);
        let mut events = Vec::new();
        let mut t = 0.0f64;
        if rate_per_s <= 0.0 {
            return FaultPlan { events };
        }
        loop {
            t += rng.exponential(rate_per_s);
            if t >= cfg.horizon_s {
                break;
            }
            let kind = *rng.choice(&cfg.kinds);
            let (magnitude, duration_s) = match kind {
                FaultKind::LinkDegradation => (
                    (mag * rng.uniform(0.3, 0.9)).min(0.95),
                    rng.uniform(60.0, 600.0),
                ),
                FaultKind::LossBurst => (
                    mag * rng.uniform(1e-4, 5e-3),
                    rng.uniform(20.0, 180.0),
                ),
                FaultKind::RttInflation => (
                    mag * rng.uniform(0.5, 3.0),
                    rng.uniform(30.0, 300.0),
                ),
                FaultKind::TrafficSurge => (
                    mag * rng.uniform(0.5, 2.0) * profile.bg_streams_peak,
                    rng.uniform(120.0, 900.0),
                ),
                FaultKind::EndpointStall => {
                    (1.0, 5.0 + mag * rng.uniform(10.0, 115.0))
                }
            };
            events.push(FaultEvent {
                kind,
                t_start_s: t,
                duration_s,
                magnitude,
            });
        }
        // exponential arrivals are already ordered, but keep the
        // invariant explicit for hand-built plans merged in later
        events.sort_by(|a, b| a.t_start_s.total_cmp(&b.t_start_s));
        FaultPlan { events }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profile() -> NetProfile {
        NetProfile::xsede()
    }

    #[test]
    fn same_seed_same_plan() {
        let cfg = FaultPlanConfig::default();
        let a = FaultPlan::generate(&profile(), &cfg, 0xF00D);
        let b = FaultPlan::generate(&profile(), &cfg, 0xF00D);
        assert_eq!(a, b);
        assert!(!a.is_empty(), "default config over 4h should schedule events");
    }

    #[test]
    fn different_seeds_differ() {
        let cfg = FaultPlanConfig::default();
        let a = FaultPlan::generate(&profile(), &cfg, 1);
        let b = FaultPlan::generate(&profile(), &cfg, 2);
        assert_ne!(a, b);
    }

    #[test]
    fn events_within_horizon_and_sorted() {
        let cfg = FaultPlanConfig {
            horizon_s: 1800.0,
            events_per_hour: 40.0,
            ..FaultPlanConfig::default()
        };
        let plan = FaultPlan::generate(&profile(), &cfg, 7);
        assert!(plan.len() > 3);
        for e in &plan.events {
            assert!(e.t_start_s >= 0.0 && e.t_start_s < cfg.horizon_s);
            assert!(e.duration_s > 0.0);
            assert!(e.magnitude >= 0.0);
        }
        for w in plan.events.windows(2) {
            assert!(w[0].t_start_s <= w[1].t_start_s);
        }
    }

    #[test]
    fn zero_intensity_is_benign_magnitudes() {
        let cfg = FaultPlanConfig::with_intensity(0.0);
        let plan = FaultPlan::generate(&profile(), &cfg, 9);
        for e in &plan.events {
            if e.kind != FaultKind::EndpointStall {
                assert_eq!(e.magnitude, 0.0, "{:?}", e.kind);
            }
        }
    }

    #[test]
    fn intensity_scales_magnitudes() {
        let mild = FaultPlan::generate(&profile(), &FaultPlanConfig::with_intensity(0.2), 11);
        let harsh = FaultPlan::generate(&profile(), &FaultPlanConfig::with_intensity(1.0), 11);
        // same seed => same arrival times and kinds, scaled magnitudes
        assert_eq!(mild.len(), harsh.len());
        for (m, h) in mild.events.iter().zip(&harsh.events) {
            assert_eq!(m.kind, h.kind);
            if m.kind != FaultKind::EndpointStall {
                assert!(h.magnitude >= m.magnitude);
            }
        }
    }

    #[test]
    fn restricted_kinds_are_respected() {
        let cfg = FaultPlanConfig {
            kinds: vec![FaultKind::LossBurst],
            events_per_hour: 20.0,
            ..FaultPlanConfig::default()
        };
        let plan = FaultPlan::generate(&profile(), &cfg, 13);
        assert!(!plan.is_empty());
        assert!(plan.events.iter().all(|e| e.kind == FaultKind::LossBurst));
    }

    #[test]
    fn event_activity_window() {
        let e = FaultEvent {
            kind: FaultKind::LossBurst,
            t_start_s: 10.0,
            duration_s: 5.0,
            magnitude: 1e-3,
        };
        assert!(!e.active_at(9.9));
        assert!(e.active_at(10.0));
        assert!(e.active_at(14.9));
        assert!(!e.active_at(15.0));
    }
}
