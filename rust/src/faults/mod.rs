//! Deterministic fault injection for the transfer stack.
//!
//! The paper's core claim is *dynamic* adaptation: the online phase
//! monitors deviation from the offline model and re-tunes protocol
//! parameters when the network changes underneath a transfer (§4.2).
//! This subsystem manufactures exactly those changes, reproducibly, so
//! the deviation monitor, the re-tuning path and the coordinator's
//! retry/resume machinery can be stress-tested.
//!
//! # Fault model
//!
//! A [`FaultPlan`] is a seed-derived schedule of [`FaultEvent`]s over a
//! time horizon. Five fault kinds are supported ([`FaultKind`]):
//!
//! * **LinkDegradation** — the bottleneck capacity drops by
//!   `magnitude` (fraction removed) and restores when the event ends;
//! * **LossBurst** — `magnitude` of extra packet-loss probability on
//!   the path (route flap, microwave fade, overloaded middlebox);
//! * **RttInflation** — RTT multiplied by `1 + magnitude` (bufferbloat
//!   or a reroute), which also shrinks the per-stream window cap;
//! * **TrafficSurge** — `magnitude` extra contending background
//!   streams at the bottleneck, beyond the diurnal process;
//! * **EndpointStall** — the remote endpoint stops responding for the
//!   event's duration; in-flight sample transfers fail and new ones
//!   cannot start until the stall clears.
//!
//! # Hook points
//!
//! Faults are injected through explicit hooks, never by mutating the
//! simulator's state ad hoc:
//!
//! * [`crate::sim::tcp::stream_rate_under_fault`] — per-stream TCP rate
//!   through a degraded profile;
//! * [`crate::sim::link::share_bottleneck_under_fault`] — water-fill
//!   over degraded capacity;
//! * [`crate::sim::engine::SimEnv::with_faults`] /
//!   [`crate::sim::engine::SimEnv::try_transfer_chunk`] — chunked
//!   single-job transfers under a plan, with fallible chunks that
//!   surface endpoint stalls to the coordinator;
//! * [`crate::sim::multiuser::MultiUserSim::with_faults`] — the shared
//!   bottleneck in the §5.4 contention simulation.
//!
//! At each chunk (or tick) the active events are folded into one
//! [`FaultState`] — overlapping capacity factors multiply, loss adds,
//! RTT factors multiply, surges add, stalls take the latest end — and
//! the state is held piecewise-constant for that chunk.
//!
//! # Determinism
//!
//! [`FaultPlan::generate`] draws every event from a
//! [`crate::util::rng::Rng`] seeded only by the caller's seed (and
//! scaled by the profile), so the same seed always yields the same
//! event sequence, and the plan itself consumes no randomness after
//! construction: replaying a transfer with the same seeds reproduces
//! the faulted run bit-for-bit. The recovery side (retry/backoff,
//! checkpoint/resume, monitor-triggered re-tuning) lives in
//! `coordinator` and `online`; `experiments/robustness` sweeps fault
//! intensity and compares recovered-throughput fractions across
//! optimizers.

pub mod engine;
pub mod plan;

pub use engine::{FaultEngine, FaultState};
pub use plan::{FaultEvent, FaultKind, FaultPlan, FaultPlanConfig};
