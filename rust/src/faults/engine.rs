//! Folding a [`FaultPlan`] into the effective network condition at an
//! instant, and deriving degraded profiles/loads for the sim hooks.

use crate::faults::plan::{FaultEvent, FaultKind, FaultPlan};
use crate::sim::profile::NetProfile;
use crate::sim::traffic::LoadState;

/// Effective fault condition at some instant: the identity state (no
/// active events) is `Default`. Overlapping events combine as
/// documented on [`FaultEngine::state_at`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultState {
    /// Multiplies bottleneck capacity (1 = healthy, < 1 = degraded).
    pub capacity_factor: f64,
    /// Added to the path's packet-loss probability.
    pub extra_loss: f64,
    /// Multiplies RTT (1 = healthy, > 1 = inflated).
    pub rtt_factor: f64,
    /// Extra contending background streams at the bottleneck.
    pub extra_bg_streams: f64,
    /// When Some, the endpoint is unresponsive until this absolute time.
    pub stalled_until_s: Option<f64>,
}

impl Default for FaultState {
    fn default() -> Self {
        FaultState {
            capacity_factor: 1.0,
            extra_loss: 0.0,
            rtt_factor: 1.0,
            extra_bg_streams: 0.0,
            stalled_until_s: None,
        }
    }
}

impl FaultState {
    /// The healthy-network identity state.
    pub fn clear() -> FaultState {
        FaultState::default()
    }

    pub fn is_clear(&self) -> bool {
        *self == FaultState::clear()
    }

    pub fn is_stalled_at(&self, t_s: f64) -> bool {
        self.stalled_until_s.is_some_and(|until| t_s < until)
    }

    /// Derive the degraded path profile: capacity and RTT scaled, base
    /// loss raised. End-system characteristics (disk, NIC, cores) are
    /// untouched — these are *network* faults.
    pub fn degrade(&self, profile: &NetProfile) -> NetProfile {
        let mut p = profile.clone();
        p.bandwidth_mbps = profile.bandwidth_mbps * self.capacity_factor;
        p.rtt_s = profile.rtt_s * self.rtt_factor;
        p.base_loss = (profile.base_loss + self.extra_loss).min(0.5);
        p
    }

    /// Fold surge streams into a load snapshot, re-normalizing the
    /// intensity against the profile's ceiling.
    pub fn surge(&self, load: LoadState, profile: &NetProfile) -> LoadState {
        if self.extra_bg_streams <= 0.0 {
            return load;
        }
        let bg = load.bg_streams + self.extra_bg_streams;
        let max_bg = profile.bg_streams_peak * 2.5;
        LoadState {
            bg_streams: bg,
            intensity: (bg / max_bg).min(1.0),
            peak: load.peak,
        }
    }
}

/// Pure, deterministic view over a plan: all randomness was spent at
/// [`FaultPlan::generate`] time, so querying consumes nothing.
#[derive(Debug, Clone)]
pub struct FaultEngine {
    plan: FaultPlan,
}

impl FaultEngine {
    pub fn new(plan: FaultPlan) -> FaultEngine {
        FaultEngine { plan }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Events active at `t_s`.
    pub fn active_at(&self, t_s: f64) -> Vec<&FaultEvent> {
        self.plan.events.iter().filter(|e| e.active_at(t_s)).collect()
    }

    /// Fold every active event into one [`FaultState`]: capacity
    /// factors multiply, loss adds, RTT factors multiply, surge streams
    /// add, and overlapping stalls keep the latest end time.
    pub fn state_at(&self, t_s: f64) -> FaultState {
        let mut s = FaultState::clear();
        for e in &self.plan.events {
            if !e.active_at(t_s) {
                continue;
            }
            match e.kind {
                FaultKind::LinkDegradation => {
                    s.capacity_factor *= (1.0 - e.magnitude).max(0.05);
                }
                FaultKind::LossBurst => s.extra_loss += e.magnitude,
                FaultKind::RttInflation => s.rtt_factor *= 1.0 + e.magnitude,
                FaultKind::TrafficSurge => s.extra_bg_streams += e.magnitude,
                FaultKind::EndpointStall => {
                    let end = e.t_end_s();
                    s.stalled_until_s = Some(
                        s.stalled_until_s.map_or(end, |cur: f64| cur.max(end)),
                    );
                }
            }
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(kind: FaultKind, start: f64, dur: f64, mag: f64) -> FaultEvent {
        FaultEvent {
            kind,
            t_start_s: start,
            duration_s: dur,
            magnitude: mag,
        }
    }

    #[test]
    fn clear_state_is_identity() {
        let s = FaultState::clear();
        assert!(s.is_clear());
        let p = NetProfile::xsede();
        assert_eq!(s.degrade(&p), p);
        let load = LoadState {
            bg_streams: 10.0,
            intensity: 0.2,
            peak: false,
        };
        assert_eq!(s.surge(load, &p), load);
    }

    #[test]
    fn degradation_scales_capacity() {
        let eng = FaultEngine::new(FaultPlan {
            events: vec![ev(FaultKind::LinkDegradation, 100.0, 50.0, 0.6)],
        });
        assert!(eng.state_at(50.0).is_clear());
        let s = eng.state_at(120.0);
        assert!((s.capacity_factor - 0.4).abs() < 1e-12);
        assert!(eng.state_at(150.0).is_clear(), "fault must restore");
        let p = NetProfile::xsede();
        let d = s.degrade(&p);
        assert!((d.bandwidth_mbps - 4000.0).abs() < 1e-6);
        assert_eq!(d.rtt_s, p.rtt_s);
    }

    #[test]
    fn overlapping_events_combine() {
        let eng = FaultEngine::new(FaultPlan {
            events: vec![
                ev(FaultKind::LinkDegradation, 0.0, 100.0, 0.5),
                ev(FaultKind::LinkDegradation, 50.0, 100.0, 0.5),
                ev(FaultKind::LossBurst, 0.0, 100.0, 1e-3),
                ev(FaultKind::LossBurst, 0.0, 100.0, 2e-3),
                ev(FaultKind::RttInflation, 0.0, 100.0, 1.0),
                ev(FaultKind::TrafficSurge, 0.0, 100.0, 12.0),
            ],
        });
        let s = eng.state_at(75.0);
        assert!((s.capacity_factor - 0.25).abs() < 1e-12);
        assert!((s.extra_loss - 3e-3).abs() < 1e-15);
        assert!((s.rtt_factor - 2.0).abs() < 1e-12);
        assert!((s.extra_bg_streams - 12.0).abs() < 1e-12);
        assert_eq!(eng.active_at(75.0).len(), 6);
    }

    #[test]
    fn stalls_keep_latest_end() {
        let eng = FaultEngine::new(FaultPlan {
            events: vec![
                ev(FaultKind::EndpointStall, 10.0, 20.0, 1.0),
                ev(FaultKind::EndpointStall, 15.0, 40.0, 1.0),
            ],
        });
        let s = eng.state_at(16.0);
        assert_eq!(s.stalled_until_s, Some(55.0));
        assert!(s.is_stalled_at(16.0));
        assert!(!s.is_stalled_at(56.0));
    }

    #[test]
    fn rtt_inflation_shrinks_window_cap() {
        let p = NetProfile::xsede();
        let s = FaultState {
            rtt_factor: 4.0,
            ..FaultState::clear()
        };
        let d = s.degrade(&p);
        assert!((d.window_cap_mbps() - p.window_cap_mbps() / 4.0).abs() < 1e-9);
    }

    #[test]
    fn surge_raises_intensity() {
        let p = NetProfile::xsede();
        let s = FaultState {
            extra_bg_streams: 60.0,
            ..FaultState::clear()
        };
        let load = LoadState {
            bg_streams: 12.0,
            intensity: 0.1,
            peak: false,
        };
        let surged = s.surge(load, &p);
        assert!((surged.bg_streams - 72.0).abs() < 1e-12);
        assert!(surged.intensity > load.intensity);
        assert!(surged.intensity <= 1.0);
    }
}
