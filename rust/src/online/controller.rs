//! The transfer-lifetime controller: ASM sampling → streaming with
//! EWMA monitoring → re-selection on persistent deviation (§4.2's
//! "whenever it detects persistent change in network condition and
//! external traffic load, it asks offline optimization module for new
//! parameters").
//!
//! The controller is the deployable unit: it implements
//! [`crate::sim::multiuser::UserPolicy`] and plugs directly into
//! `SimEnv::run_transfer` closures and the coordinator's orchestrator.

use crate::offline::cache::CachedTuning;
use crate::offline::pipeline::SurfaceSet;
use crate::online::asm::{Asm, AsmPhase};
use crate::online::monitor::{AlarmLevel, DeviationMonitor};
use crate::sim::multiuser::{UserCtx, UserPolicy};
use crate::util::json::Value;
use crate::util::trace::PendingEvent;
use crate::Params;

fn params_fields(p: Params) -> Vec<(&'static str, Value)> {
    vec![
        ("cc", Value::Num(p.cc as f64)),
        ("p", Value::Num(p.p as f64)),
        ("pp", Value::Num(p.pp as f64)),
    ]
}

/// Tuning knobs for the streaming-phase monitor.
#[derive(Debug, Clone)]
pub struct TunerConfig {
    pub ewma_alpha: f64,
    /// consecutive out-of-band smoothed samples before re-tuning
    pub deviation_streak: usize,
    /// widen the surface band by this factor during streaming (chunk
    /// measurements are noisier than dedicated sample transfers)
    pub band_slack: f64,
}

impl Default for TunerConfig {
    fn default() -> Self {
        TunerConfig {
            ewma_alpha: 0.4,
            deviation_streak: 3,
            band_slack: 1.5,
        }
    }
}

/// Full online controller for one transfer.
#[derive(Debug, Clone)]
pub struct DynamicTuner {
    asm: Asm,
    monitor: DeviationMonitor,
    cfg: TunerConfig,
    /// how many times the streaming phase re-tuned
    pub retunes: usize,
    /// trace events minted since the last [`DynamicTuner::drain_trace`];
    /// the tuner has no clock, so the orchestrator stamps them with the
    /// sim time of the chunk that produced them
    pending: Vec<PendingEvent>,
    /// last alarm level reported, so only *transitions* are traced
    last_alarm: AlarmLevel,
}

impl DynamicTuner {
    pub fn new(set: SurfaceSet, cfg: TunerConfig) -> DynamicTuner {
        let monitor = DeviationMonitor::new(cfg.ewma_alpha, cfg.deviation_streak);
        DynamicTuner {
            asm: Asm::new(set),
            monitor,
            cfg,
            retunes: 0,
            pending: Vec::new(),
            last_alarm: AlarmLevel::Clear,
        }
    }

    pub fn with_defaults(set: SurfaceSet) -> DynamicTuner {
        DynamicTuner::new(set, TunerConfig::default())
    }

    /// Construct warm-started from a historical tuning-cache entry:
    /// the ASM begins in its streaming phase at the cached bucket,
    /// spending zero sample transfers.  Falls back to cold sampling
    /// when the entry no longer matches this surface set (bucket gone,
    /// or the bucket's optimum moved since the entry was recorded) —
    /// a stale replay would stream at the wrong operating point.
    pub fn with_cached(
        set: SurfaceSet,
        cfg: TunerConfig,
        cached: &CachedTuning,
    ) -> DynamicTuner {
        let mut tuner = DynamicTuner::new(set, cfg);
        if tuner.asm.warm_start(cached.bucket) && tuner.asm.params() != cached.params {
            tuner.asm.restart();
        }
        tuner
    }

    /// Parameters for the next chunk.
    pub fn params(&self) -> Params {
        self.asm.params()
    }

    pub fn phase(&self) -> AsmPhase {
        self.asm.phase()
    }

    pub fn samples_used(&self) -> usize {
        self.asm.samples_used()
    }

    /// Surface-predicted throughput at the operating point.
    pub fn predicted(&self) -> f64 {
        self.asm.predicted()
    }

    /// Feed the measured throughput of the chunk transferred with
    /// [`DynamicTuner::params`]; returns the parameters for the next
    /// chunk.
    pub fn observe(&mut self, measured: f64) -> Params {
        match self.asm.phase() {
            AsmPhase::Sampling => {
                let bucket_before = self.asm.current_bucket();
                let d = self.asm.observe(measured);
                let mut fields = vec![
                    ("measured_mbps", Value::Num(measured)),
                    ("bucket", Value::Num(bucket_before as f64)),
                    ("samples_used", Value::Num(self.asm.samples_used() as f64)),
                ];
                fields.extend(params_fields(d.params));
                self.pending.push(PendingEvent::new("asm.sample", fields));
                if d.phase == AsmPhase::Streaming {
                    self.monitor.reset();
                    self.last_alarm = AlarmLevel::Clear;
                    let mut fields = vec![
                        ("bucket", Value::Num(self.asm.current_bucket() as f64)),
                        ("samples_used", Value::Num(self.asm.samples_used() as f64)),
                        ("predicted_mbps", Value::Num(self.asm.predicted())),
                    ];
                    fields.extend(params_fields(d.params));
                    self.pending
                        .push(PendingEvent::new("asm.converged", fields));
                }
                d.params
            }
            AsmPhase::Streaming => {
                let predicted = self.asm.predicted();
                let band = self.asm.band() * self.cfg.band_slack;
                let level = self.monitor.observe_level(predicted, band, measured);
                if level != self.last_alarm {
                    self.pending.push(PendingEvent::new(
                        "monitor.alarm",
                        vec![
                            ("level", Value::str(level.label())),
                            ("predicted_mbps", Value::Num(predicted)),
                            ("band_mbps", Value::Num(band)),
                            (
                                "smoothed_mbps",
                                Value::Num(self.monitor.smoothed().unwrap_or(measured)),
                            ),
                        ],
                    ));
                    self.last_alarm = level;
                }
                if level == AlarmLevel::Confirmed {
                    let recent = self.monitor.smoothed().unwrap_or(measured);
                    let from_bucket = self.asm.current_bucket();
                    let d = self.asm.reselect(recent);
                    self.monitor.reset();
                    self.last_alarm = AlarmLevel::Clear;
                    self.retunes += 1;
                    let mut fields = vec![
                        ("from_bucket", Value::Num(from_bucket as f64)),
                        ("to_bucket", Value::Num(self.asm.current_bucket() as f64)),
                        ("recent_mbps", Value::Num(recent)),
                        ("retunes", Value::Num(self.retunes as f64)),
                    ];
                    fields.extend(params_fields(d.params));
                    self.pending.push(PendingEvent::new("asm.retune", fields));
                    d.params
                } else {
                    self.asm.params()
                }
            }
        }
    }

    /// Recovery re-arm: after a confirmed fault the coordinator calls
    /// this to restart the ASM bisection (fresh Algorithm-1 pass over
    /// the surface stack) and clear the monitor's stale EWMA state.
    pub fn rearm(&mut self) {
        self.asm.restart();
        self.monitor.reset();
        self.last_alarm = AlarmLevel::Clear;
        self.pending.push(PendingEvent::new(
            "asm.rearm",
            vec![("bucket", Value::Num(self.asm.current_bucket() as f64))],
        ));
    }

    /// Take the trace events minted since the last drain.  Events are
    /// clock-less — the caller stamps them with the sim time of the
    /// chunk that produced them (see `util::trace::TraceScope::stamp`).
    /// The buffer is bounded by chunk count between drains; untraced
    /// callers simply never drain and drop the events with the tuner.
    pub fn drain_trace(&mut self) -> Vec<PendingEvent> {
        std::mem::take(&mut self.pending)
    }

    pub fn asm(&self) -> &Asm {
        &self.asm
    }
}

impl UserPolicy for DynamicTuner {
    fn decide(&mut self, ctx: &UserCtx) -> Params {
        match ctx.last_throughput {
            None => self.params(),
            Some(th) => self.observe(th),
        }
    }

    fn name(&self) -> &str {
        "ASM"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offline::confidence::ConfidenceRegion;
    use crate::offline::pipeline::LoadBucketSurfaces;
    use crate::offline::spline::BicubicSurface;
    use crate::offline::surface::{knot_lattice, FittedSurface, ThroughputSurface};

    fn set_with_levels(levels: &[f64]) -> SurfaceSet {
        let xs = knot_lattice();
        let buckets = levels
            .iter()
            .enumerate()
            .map(|(i, &lvl)| {
                let values: Vec<Vec<f64>> =
                    xs.iter().map(|_| xs.iter().map(|_| lvl).collect()).collect();
                let surface = BicubicSurface::fit(&xs, &xs, &values);
                let slice = ThroughputSurface {
                    pp: 8,
                    load_bucket: i,
                    load_intensity: i as f64 / levels.len() as f64,
                    fitted: FittedSurface {
                        surface,
                        max_th: lvl,
                        max_at: (8.0, 8.0),
                        grid_mean: lvl,
                        grid_std: 1.0,
                    },
                    confidence: ConfidenceRegion { sigma: 20.0, z: 2.0 },
                    optimal_params: Params::new(8, 8, 8),
                    optimal_th: lvl,
                    n_obs: 64,
                    coverage: 1.0,
                };
                LoadBucketSurfaces {
                    bucket: i,
                    load_intensity: i as f64 / levels.len() as f64,
                    true_intensity: i as f64 / levels.len() as f64,
                    slices: vec![slice],
                    optimal_params: Params::new(8, 8, 8),
                    optimal_th: lvl,
                }
            })
            .collect();
        SurfaceSet {
            cluster: 0,
            class: crate::sim::dataset::FileSizeClass::Large,
            buckets,
            sampling: vec![],
        }
    }

    #[test]
    fn samples_then_streams() {
        let mut t = DynamicTuner::with_defaults(set_with_levels(&[1000.0, 600.0, 200.0]));
        assert_eq!(t.phase(), AsmPhase::Sampling);
        t.observe(600.0); // inside median band
        assert_eq!(t.phase(), AsmPhase::Streaming);
        assert_eq!(t.samples_used(), 1);
    }

    #[test]
    fn noise_does_not_retune() {
        let mut t = DynamicTuner::with_defaults(set_with_levels(&[1000.0, 600.0, 200.0]));
        t.observe(600.0);
        for _ in 0..50 {
            t.observe(600.0 + if t.retunes == 0 { 25.0 } else { 0.0 });
        }
        assert_eq!(t.retunes, 0);
    }

    #[test]
    fn sustained_load_change_retunes_to_matching_surface() {
        let mut t = DynamicTuner::with_defaults(set_with_levels(&[1000.0, 600.0, 200.0]));
        t.observe(600.0); // converge on the middle bucket
        assert_eq!(t.asm().current_bucket(), 1);
        // heavy external load arrives: measured drops to ~200
        for _ in 0..10 {
            t.observe(200.0);
        }
        assert!(t.retunes >= 1, "should have re-tuned");
        assert_eq!(t.asm().current_bucket(), 2);
    }

    #[test]
    fn recovery_after_congestion_clears() {
        let mut t = DynamicTuner::with_defaults(set_with_levels(&[1000.0, 600.0, 200.0]));
        t.observe(600.0);
        for _ in 0..10 {
            t.observe(200.0); // congestion
        }
        assert_eq!(t.asm().current_bucket(), 2);
        for _ in 0..10 {
            t.observe(980.0); // congestion cleared, link near-idle
        }
        assert_eq!(t.asm().current_bucket(), 0, "should climb back up");
        assert!(t.retunes >= 2);
    }

    #[test]
    fn rearm_restarts_sampling_with_clean_monitor() {
        let mut t = DynamicTuner::with_defaults(set_with_levels(&[1000.0, 600.0, 200.0]));
        t.observe(600.0); // converge, start streaming
        assert_eq!(t.phase(), AsmPhase::Streaming);
        t.observe(180.0); // fault hits: deviation building
        t.rearm();
        assert_eq!(t.phase(), AsmPhase::Sampling, "bisection reopened");
        assert_eq!(t.asm().current_bucket(), 1, "back at the median");
        assert!(t.monitor.smoothed().is_none(), "monitor state cleared");
        // converges again on post-fault conditions
        t.observe(200.0);
        t.observe(200.0);
        assert_eq!(t.phase(), AsmPhase::Streaming);
        assert_eq!(t.asm().current_bucket(), 2);
    }

    #[test]
    fn cached_warm_start_streams_without_sampling() {
        let cached = CachedTuning {
            params: Params::new(8, 8, 8),
            predicted_mbps: 200.0,
            bucket: 2,
        };
        let t = DynamicTuner::with_cached(
            set_with_levels(&[1000.0, 600.0, 200.0]),
            TunerConfig::default(),
            &cached,
        );
        assert_eq!(t.phase(), AsmPhase::Streaming);
        assert_eq!(t.asm().current_bucket(), 2);
        assert_eq!(t.samples_used(), 0);
    }

    #[test]
    fn stale_cache_entry_falls_back_to_sampling() {
        // bucket index out of range → cold start
        let gone = CachedTuning {
            params: Params::new(8, 8, 8),
            predicted_mbps: 500.0,
            bucket: 7,
        };
        let t = DynamicTuner::with_cached(
            set_with_levels(&[1000.0, 600.0, 200.0]),
            TunerConfig::default(),
            &gone,
        );
        assert_eq!(t.phase(), AsmPhase::Sampling);
        // bucket exists but its optimum moved since the entry was cut
        let moved = CachedTuning {
            params: Params::new(4, 4, 4),
            predicted_mbps: 600.0,
            bucket: 1,
        };
        let t = DynamicTuner::with_cached(
            set_with_levels(&[1000.0, 600.0, 200.0]),
            TunerConfig::default(),
            &moved,
        );
        assert_eq!(t.phase(), AsmPhase::Sampling);
        assert_eq!(t.asm().current_bucket(), 1, "restart() re-medians");
    }

    #[test]
    fn trace_events_cover_sampling_convergence_and_retune() {
        let mut t = DynamicTuner::with_defaults(set_with_levels(&[1000.0, 600.0, 200.0]));
        t.observe(600.0); // converge
        let names: Vec<&str> = t.pending.iter().map(|e| e.name).collect();
        assert!(names.contains(&"asm.sample"));
        assert!(names.contains(&"asm.converged"));
        let drained = t.drain_trace();
        assert_eq!(drained.len(), names.len());
        assert!(t.pending.is_empty(), "drain takes everything");
        // sustained load change → alarm transitions then a re-tune
        for _ in 0..10 {
            t.observe(200.0);
        }
        let names: Vec<&str> = t.drain_trace().iter().map(|e| e.name).collect();
        assert!(names.contains(&"monitor.alarm"));
        assert!(names.contains(&"asm.retune"));
        // re-arm after a fault
        t.rearm();
        let names: Vec<&str> = t.drain_trace().iter().map(|e| e.name).collect();
        assert_eq!(names, vec!["asm.rearm"]);
    }

    #[test]
    fn alarm_events_only_on_transitions() {
        let mut t = DynamicTuner::with_defaults(set_with_levels(&[1000.0, 600.0, 200.0]));
        t.observe(600.0);
        t.drain_trace();
        for _ in 0..20 {
            t.observe(600.0); // in band the whole time
        }
        assert!(
            t.drain_trace().is_empty(),
            "steady in-band streaming mints no events"
        );
    }

    #[test]
    fn user_policy_interface() {
        let mut t = DynamicTuner::with_defaults(set_with_levels(&[1000.0, 600.0, 200.0]));
        let first = t.decide(&UserCtx {
            user_id: 0,
            t_s: 0.0,
            last_throughput: None,
            current_params: Params::DEFAULT,
            decision_idx: 0,
        });
        assert_eq!(first, Params::new(8, 8, 8));
        let next = t.decide(&UserCtx {
            user_id: 0,
            t_s: 20.0,
            last_throughput: Some(600.0),
            current_params: first,
            decision_idx: 1,
        });
        assert_eq!(next, Params::new(8, 8, 8));
        assert_eq!(t.phase(), AsmPhase::Streaming);
        assert_eq!(UserPolicy::name(&t), "ASM");
    }
}
