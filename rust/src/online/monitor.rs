//! Persistent-deviation detection for the streaming phase.
//!
//! A single out-of-confidence chunk is probably noise; the paper reacts
//! only to "persistent change in network condition and external traffic
//! load".  We smooth measurements with an EWMA and require `streak`
//! consecutive out-of-band smoothed values before declaring a change.

use crate::util::stats::Ewma;

/// Graded deviation alarm: a [`Warning`] means the smoothed signal is
/// out of band but the streak is still building (could be a fault
/// transient); [`Confirmed`] means the deviation is persistent and the
/// re-tuning path (re-query the knowledge base, re-run the ASM) should
/// fire.
///
/// [`Warning`]: AlarmLevel::Warning
/// [`Confirmed`]: AlarmLevel::Confirmed
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlarmLevel {
    /// smoothed measurement inside the confidence band
    Clear,
    /// out of band, streak not yet complete
    Warning,
    /// persistent deviation — re-tune now
    Confirmed,
}

impl AlarmLevel {
    /// Stable lowercase name (trace records, reports).
    pub fn label(&self) -> &'static str {
        match self {
            AlarmLevel::Clear => "clear",
            AlarmLevel::Warning => "warning",
            AlarmLevel::Confirmed => "confirmed",
        }
    }
}

#[derive(Debug, Clone)]
pub struct DeviationMonitor {
    ewma: Ewma,
    out_streak: usize,
    /// consecutive out-of-band observations required
    streak: usize,
}

impl DeviationMonitor {
    pub fn new(alpha: f64, streak: usize) -> DeviationMonitor {
        DeviationMonitor {
            ewma: Ewma::new(alpha),
            out_streak: 0,
            streak: streak.max(1),
        }
    }

    /// Feed one measurement against the surface prediction ± band.
    /// Returns true when the deviation is persistent.
    pub fn observe(&mut self, predicted: f64, band: f64, measured: f64) -> bool {
        self.observe_level(predicted, band, measured) == AlarmLevel::Confirmed
    }

    /// Like [`DeviationMonitor::observe`] but exposes the graded alarm,
    /// letting fault-aware callers distinguish "watch closely" from
    /// "act".
    pub fn observe_level(&mut self, predicted: f64, band: f64, measured: f64) -> AlarmLevel {
        let smoothed = self.ewma.update(measured);
        if (smoothed - predicted).abs() > band {
            // cap at `streak`: the alarm state machine stays finite, and
            // a long Confirmed stretch can't bank extra streak credit
            // that would survive a reset()/re-tune race and mask how
            // quickly a *fresh* deviation re-confirms
            self.out_streak = (self.out_streak + 1).min(self.streak);
        } else {
            self.out_streak = 0;
        }
        if self.out_streak >= self.streak {
            AlarmLevel::Confirmed
        } else if self.out_streak > 0 {
            AlarmLevel::Warning
        } else {
            AlarmLevel::Clear
        }
    }

    /// The smoothed throughput estimate (for surface re-selection).
    pub fn smoothed(&self) -> Option<f64> {
        self.ewma.value()
    }

    /// Reset after a re-tune (new surface, new band).
    pub fn reset(&mut self) {
        self.ewma.reset();
        self.out_streak = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn single_outlier_does_not_trigger() {
        // a lone spike pushes the EWMA out once, but good samples pull
        // it back inside the band before the streak completes
        let mut m = DeviationMonitor::new(0.5, 3);
        assert!(!m.observe(100.0, 60.0, 100.0));
        assert!(!m.observe(100.0, 60.0, 300.0)); // spike: smoothed 200
        assert!(!m.observe(100.0, 60.0, 100.0)); // smoothed 150, back in
        assert!(!m.observe(100.0, 60.0, 100.0));
        assert!(!m.observe(100.0, 60.0, 100.0));
    }

    #[test]
    fn sustained_shift_triggers_after_streak() {
        let mut m = DeviationMonitor::new(0.6, 3);
        m.observe(100.0, 10.0, 100.0);
        let mut fired = 0;
        for i in 0..6 {
            if m.observe(100.0, 10.0, 200.0) {
                fired = i + 1;
                break;
            }
        }
        assert!(
            (3..=4).contains(&fired),
            "should fire after ~3 sustained deviations, got {fired}"
        );
    }

    #[test]
    fn noise_within_band_never_triggers() {
        let mut rng = Rng::new(2);
        let mut m = DeviationMonitor::new(0.3, 3);
        for _ in 0..500 {
            let v = rng.normal_ms(100.0, 3.0);
            assert!(!m.observe(100.0, 15.0, v));
        }
    }

    #[test]
    fn reset_clears_state() {
        let mut m = DeviationMonitor::new(0.6, 2);
        m.observe(100.0, 5.0, 200.0);
        m.observe(100.0, 5.0, 200.0);
        m.reset();
        assert!(m.smoothed().is_none());
        assert!(!m.observe(100.0, 5.0, 100.0));
    }

    #[test]
    fn alarm_escalates_warning_then_confirmed() {
        let mut m = DeviationMonitor::new(0.9, 3);
        assert_eq!(m.observe_level(100.0, 10.0, 100.0), AlarmLevel::Clear);
        assert_eq!(m.observe_level(100.0, 10.0, 300.0), AlarmLevel::Warning);
        assert_eq!(m.observe_level(100.0, 10.0, 300.0), AlarmLevel::Warning);
        assert_eq!(m.observe_level(100.0, 10.0, 300.0), AlarmLevel::Confirmed);
        // smoothed signal needs a tick to come back (ewma ≈ 120: still out)
        assert_eq!(m.observe_level(100.0, 10.0, 100.5), AlarmLevel::Confirmed);
        // once it is inside the band the streak resets straight to Clear
        assert_eq!(m.observe_level(100.0, 10.0, 100.0), AlarmLevel::Clear);
    }

    #[test]
    fn observe_matches_confirmed_level() {
        let mut a = DeviationMonitor::new(0.6, 2);
        let mut b = DeviationMonitor::new(0.6, 2);
        for &v in &[100.0, 250.0, 250.0, 250.0, 100.0, 100.0] {
            let fired = a.observe(100.0, 20.0, v);
            let level = b.observe_level(100.0, 20.0, v);
            assert_eq!(fired, level == AlarmLevel::Confirmed);
        }
    }

    #[test]
    fn out_streak_is_capped_at_streak() {
        // a long Confirmed stretch must not bank streak credit: after
        // the signal returns in band once, a fresh deviation needs the
        // full streak again — capped or not, the recovery behavior is
        // observable through how fast Confirmed re-fires
        let mut m = DeviationMonitor::new(1.0, 3); // alpha 1: no smoothing
        m.observe_level(100.0, 10.0, 100.0);
        for _ in 0..50 {
            assert_ne!(m.observe_level(100.0, 10.0, 300.0), AlarmLevel::Clear);
        }
        assert_eq!(m.out_streak, m.streak, "streak must saturate, not grow");
        // back in band once → Clear, then a fresh deviation re-escalates
        // through the full Warning ramp
        assert_eq!(m.observe_level(100.0, 10.0, 100.0), AlarmLevel::Clear);
        assert_eq!(m.observe_level(100.0, 10.0, 300.0), AlarmLevel::Warning);
        assert_eq!(m.observe_level(100.0, 10.0, 300.0), AlarmLevel::Warning);
        assert_eq!(m.observe_level(100.0, 10.0, 300.0), AlarmLevel::Confirmed);
    }

    #[test]
    fn alarm_level_labels() {
        assert_eq!(AlarmLevel::Clear.label(), "clear");
        assert_eq!(AlarmLevel::Warning.label(), "warning");
        assert_eq!(AlarmLevel::Confirmed.label(), "confirmed");
    }

    #[test]
    fn smoothed_tracks_mean() {
        let mut m = DeviationMonitor::new(0.4, 3);
        for _ in 0..50 {
            m.observe(100.0, 50.0, 140.0);
        }
        assert!((m.smoothed().unwrap() - 140.0).abs() < 1.0);
    }
}
