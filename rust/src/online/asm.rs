//! The Adaptive Sampling Module — Algorithm 1 of the paper.
//!
//! `QueryDB` (the [`crate::offline::SurfaceSet`]) hands us surfaces
//! sorted by external-load intensity.  Sampling starts at the *median*
//! bucket's precomputed optimum (line 3–6); each sample transfer's
//! achieved throughput is tested against the surface's Gaussian
//! confidence bound:
//!
//! * inside the bound → the surface represents current load: converge
//!   and stream the rest of the dataset with its optimal parameters;
//! * above the bound → the network is lighter than this surface's tag:
//!   discard every bucket at or above the current intensity and bisect
//!   into the lighter half;
//! * below the bound → heavier: bisect into the heavier half.
//!
//! Each sample halves the candidate stack ("the algorithm can get rid
//! of half the surfaces at each transfer"), so convergence takes at
//! most ⌈log₂ η⌉ + 1 samples.

use crate::offline::pipeline::SurfaceSet;
use crate::Params;

/// Where the ASM is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AsmPhase {
    /// still bisecting the surface stack with sample transfers
    Sampling,
    /// converged; streaming at the selected bucket's optimum
    Streaming,
}

/// The decision returned after each observation.
#[derive(Debug, Clone, Copy)]
pub struct AsmDecision {
    pub params: Params,
    pub phase: AsmPhase,
    /// bucket index currently trusted
    pub bucket: usize,
    /// surface-predicted throughput at `params`
    pub predicted: f64,
}

/// Algorithm-1 state over one queried surface set.
#[derive(Debug, Clone)]
pub struct Asm {
    set: SurfaceSet,
    lo: usize,
    hi: usize,
    current: usize,
    phase: AsmPhase,
    samples_used: usize,
}

impl Asm {
    /// Start a transfer: first sample at the median-load surface.
    pub fn new(set: SurfaceSet) -> Asm {
        assert!(!set.buckets.is_empty(), "surface set has no buckets");
        let hi = set.buckets.len() - 1;
        let current = set.median_bucket();
        Asm {
            set,
            lo: 0,
            hi,
            current,
            phase: AsmPhase::Sampling,
            samples_used: 0,
        }
    }

    pub fn phase(&self) -> AsmPhase {
        self.phase
    }

    pub fn samples_used(&self) -> usize {
        self.samples_used
    }

    pub fn current_bucket(&self) -> usize {
        self.current
    }

    /// Parameters for the next (sample or stream) transfer.
    pub fn params(&self) -> Params {
        self.set.buckets[self.current].optimal_params
    }

    /// Surface prediction at the current parameters.
    pub fn predicted(&self) -> f64 {
        let b = &self.set.buckets[self.current];
        b.predict(b.optimal_params)
    }

    /// Maximum sample transfers the bisection can take.
    pub fn max_samples(&self) -> usize {
        (self.set.buckets.len() as f64).log2().ceil() as usize + 1
    }

    /// Feed the achieved throughput of the transfer that used
    /// [`Asm::params`]; returns the next decision.
    pub fn observe(&mut self, achieved: f64) -> AsmDecision {
        let b = &self.set.buckets[self.current];
        let predicted = b.predict(b.optimal_params);
        let slice = b.slice_for(b.optimal_params);
        let dev = slice.confidence.deviation_sigmas(predicted, achieved);
        let inside = dev.abs() <= slice.confidence.z;

        match self.phase {
            AsmPhase::Sampling => {
                self.samples_used += 1;
                if inside || self.lo >= self.hi {
                    // converged (or the stack is exhausted)
                    self.phase = AsmPhase::Streaming;
                } else if dev > 0.0 {
                    // lighter network than this surface's load tag:
                    // drop this bucket and everything heavier
                    self.hi = self.current.saturating_sub(1).max(self.lo);
                    self.current = (self.lo + self.hi) / 2;
                    if self.lo >= self.hi {
                        self.phase = AsmPhase::Streaming;
                    }
                } else {
                    // heavier: drop this bucket and everything lighter
                    self.lo = (self.current + 1).min(self.hi);
                    self.current = (self.lo + self.hi + 1) / 2;
                    if self.lo >= self.hi {
                        self.phase = AsmPhase::Streaming;
                    }
                }
            }
            AsmPhase::Streaming => {
                // streaming-phase re-selection is the controller's job
                // (it filters noise first); nothing to do here.
            }
        }
        self.decision()
    }

    /// Restart the bisection from scratch (recovery path: after a
    /// confirmed fault the pre-fault surface choice is stale, so the
    /// coordinator re-queries the knowledge base and re-runs Algorithm
    /// 1 from the median bucket).  `samples_used` keeps accumulating —
    /// recovery samples are real sample transfers.
    pub fn restart(&mut self) {
        self.lo = 0;
        self.hi = self.set.buckets.len() - 1;
        self.current = self.set.median_bucket();
        self.phase = AsmPhase::Sampling;
    }

    /// Warm-start from a cached converged bucket (the historical
    /// tuning cache's replay path): skip the bisection entirely and
    /// stream at `bucket`'s optimum straight away.  Returns false with
    /// the state untouched when the bucket index no longer exists —
    /// e.g. the knowledge base was rebuilt with fewer buckets — in
    /// which case the caller falls back to ordinary sampling.  The
    /// deviation monitor still guards a stale warm start: a persistent
    /// mismatch mid-stream triggers the usual [`Asm::reselect`].
    pub fn warm_start(&mut self, bucket: usize) -> bool {
        if bucket >= self.set.buckets.len() {
            return false;
        }
        self.current = bucket;
        self.phase = AsmPhase::Streaming;
        true
    }

    /// Re-select the bucket whose prediction is closest to a measured
    /// throughput (the "FindClosestSurface" of Algorithm 1, used after
    /// a persistent deviation mid-stream).
    pub fn reselect(&mut self, measured: f64) -> AsmDecision {
        let mut best = (self.current, f64::INFINITY);
        for (i, b) in self.set.buckets.iter().enumerate() {
            let pred = b.predict(b.optimal_params);
            let d = (pred - measured).abs();
            if d < best.1 {
                best = (i, d);
            }
        }
        self.current = best.0;
        // re-open the bisection window around the new bucket so a later
        // harsh change can bisect again
        self.lo = 0;
        self.hi = self.set.buckets.len() - 1;
        self.decision()
    }

    pub fn decision(&self) -> AsmDecision {
        AsmDecision {
            params: self.params(),
            phase: self.phase,
            bucket: self.current,
            predicted: self.predicted(),
        }
    }

    /// Confidence band (±) at the current operating point.
    pub fn band(&self) -> f64 {
        let b = &self.set.buckets[self.current];
        b.slice_for(b.optimal_params).confidence.band()
    }

    pub fn set(&self) -> &SurfaceSet {
        &self.set
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offline::confidence::ConfidenceRegion;
    use crate::offline::pipeline::LoadBucketSurfaces;
    use crate::offline::spline::BicubicSurface;
    use crate::offline::surface::{knot_lattice, FittedSurface, ThroughputSurface};

    /// Synthetic surface set: bucket i predicts a flat surface at
    /// level[i] with σ = 20 (z = 2 → band 40), optimum at (8, 8).
    fn set_with_levels(levels: &[f64]) -> SurfaceSet {
        let xs = knot_lattice();
        let buckets = levels
            .iter()
            .enumerate()
            .map(|(i, &lvl)| {
                let values: Vec<Vec<f64>> = xs
                    .iter()
                    .map(|&p| {
                        xs.iter()
                            .map(|&cc| lvl - 0.5 * ((p - 8.0).abs() + (cc - 8.0).abs()))
                            .collect()
                    })
                    .collect();
                let surface = BicubicSurface::fit(&xs, &xs, &values);
                let slice = ThroughputSurface {
                    pp: 8,
                    load_bucket: i,
                    load_intensity: i as f64 / levels.len() as f64,
                    fitted: FittedSurface {
                        surface,
                        max_th: lvl,
                        max_at: (8.0, 8.0),
                        grid_mean: lvl,
                        grid_std: 1.0,
                    },
                    confidence: ConfidenceRegion { sigma: 20.0, z: 2.0 },
                    optimal_params: Params::new(8, 8, 8),
                    optimal_th: lvl,
                    n_obs: 64,
                    coverage: 1.0,
                };
                LoadBucketSurfaces {
                    bucket: i,
                    load_intensity: i as f64 / levels.len() as f64,
                    true_intensity: i as f64 / levels.len() as f64,
                    slices: vec![slice],
                    optimal_params: Params::new(8, 8, 8),
                    optimal_th: lvl,
                }
            })
            .collect();
        SurfaceSet {
            cluster: 0,
            class: crate::sim::dataset::FileSizeClass::Large,
            buckets,
            sampling: vec![],
        }
    }

    /// Buckets sorted by load ascending: lightest has the highest level.
    fn five_levels() -> Vec<f64> {
        vec![1000.0, 800.0, 600.0, 400.0, 200.0]
    }

    #[test]
    fn starts_at_median_bucket() {
        let asm = Asm::new(set_with_levels(&five_levels()));
        assert_eq!(asm.current_bucket(), 2);
        assert_eq!(asm.params(), Params::new(8, 8, 8));
        assert_eq!(asm.phase(), AsmPhase::Sampling);
    }

    #[test]
    fn converges_immediately_when_inside_bound() {
        let mut asm = Asm::new(set_with_levels(&five_levels()));
        // median predicts 600; achieved 590 is inside ±40
        let d = asm.observe(590.0);
        assert_eq!(d.phase, AsmPhase::Streaming);
        assert_eq!(asm.samples_used(), 1);
        assert_eq!(d.bucket, 2);
    }

    #[test]
    fn bisects_to_lightest_when_network_is_idle() {
        let mut asm = Asm::new(set_with_levels(&five_levels()));
        // network actually supports ~1000 (lightest bucket)
        let mut d = asm.decision();
        for _ in 0..asm.max_samples() {
            if d.phase == AsmPhase::Streaming {
                break;
            }
            d = asm.observe(1000.0);
        }
        assert_eq!(d.phase, AsmPhase::Streaming);
        assert_eq!(d.bucket, 0, "should land on the lightest bucket");
        assert!(asm.samples_used() <= asm.max_samples());
    }

    #[test]
    fn bisects_to_heaviest_under_load() {
        let mut asm = Asm::new(set_with_levels(&five_levels()));
        let mut d = asm.decision();
        for _ in 0..asm.max_samples() {
            if d.phase == AsmPhase::Streaming {
                break;
            }
            d = asm.observe(200.0);
        }
        assert_eq!(d.bucket, 4, "should land on the heaviest bucket");
    }

    #[test]
    fn lands_on_intermediate_bucket() {
        let mut asm = Asm::new(set_with_levels(&five_levels()));
        // true level ~800 = bucket 1
        let mut d = asm.decision();
        for _ in 0..asm.max_samples() {
            if d.phase == AsmPhase::Streaming {
                break;
            }
            d = asm.observe(800.0);
        }
        assert_eq!(d.bucket, 1);
    }

    #[test]
    fn sample_budget_is_logarithmic() {
        for n in [1usize, 2, 3, 5, 8, 16] {
            let levels: Vec<f64> = (0..n).map(|i| 1000.0 - 100.0 * i as f64).collect();
            let mut asm = Asm::new(set_with_levels(&levels));
            let budget = asm.max_samples();
            assert!(budget <= (n as f64).log2().ceil() as usize + 1);
            // drive to convergence with an extreme observation
            let mut steps = 0;
            while asm.phase() == AsmPhase::Sampling && steps < 20 {
                asm.observe(1.0);
                steps += 1;
            }
            assert!(
                asm.samples_used() <= budget,
                "n={n}: used {} > budget {budget}",
                asm.samples_used()
            );
        }
    }

    #[test]
    fn reselect_finds_closest_surface() {
        let mut asm = Asm::new(set_with_levels(&five_levels()));
        asm.observe(590.0); // converge at bucket 2
        let d = asm.reselect(410.0);
        assert_eq!(d.bucket, 3, "400-level bucket is closest to 410");
        let d2 = asm.reselect(990.0);
        assert_eq!(d2.bucket, 0);
    }

    #[test]
    fn restart_reopens_bisection_from_median() {
        let mut asm = Asm::new(set_with_levels(&five_levels()));
        // drive to the heaviest bucket and converge
        while asm.phase() == AsmPhase::Sampling {
            asm.observe(200.0);
        }
        assert_eq!(asm.current_bucket(), 4);
        let used = asm.samples_used();
        asm.restart();
        assert_eq!(asm.phase(), AsmPhase::Sampling);
        assert_eq!(asm.current_bucket(), 2, "back at the median");
        assert_eq!(asm.samples_used(), used, "history is kept");
        // and it can converge somewhere else this time
        while asm.phase() == AsmPhase::Sampling {
            asm.observe(1000.0);
        }
        assert_eq!(asm.current_bucket(), 0);
    }

    #[test]
    fn warm_start_skips_sampling_and_validates_bucket() {
        let mut asm = Asm::new(set_with_levels(&five_levels()));
        assert!(asm.warm_start(3));
        assert_eq!(asm.phase(), AsmPhase::Streaming);
        assert_eq!(asm.current_bucket(), 3);
        assert_eq!(asm.samples_used(), 0, "no sample transfers were spent");
        // out-of-range bucket (stale cache): refused, state untouched
        assert!(!asm.warm_start(99));
        assert_eq!(asm.current_bucket(), 3);
        // a stale warm start can still be corrected mid-stream
        let d = asm.reselect(990.0);
        assert_eq!(d.bucket, 0);
    }

    #[test]
    fn single_bucket_set_converges_in_one() {
        let mut asm = Asm::new(set_with_levels(&[500.0]));
        let d = asm.observe(123.0); // wildly off, but nowhere to go
        assert_eq!(d.phase, AsmPhase::Streaming);
        assert_eq!(d.bucket, 0);
    }
}
