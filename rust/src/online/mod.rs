//! The online phase (§4.2): dynamic control during a live transfer.
//!
//! * [`asm`] — the Adaptive Sampling Module (Algorithm 1): start from
//!   the median-load surface's precomputed optimum, then bisect the
//!   load-sorted surface stack on confidence-bound violations ("the
//!   algorithm can get rid of half the surfaces at each transfer");
//! * [`monitor`] — EWMA persistent-deviation detector that separates
//!   harsh external-load changes from sampling noise;
//! * [`controller`] — the full transfer-lifetime state machine gluing
//!   the two together (sampling → streaming → re-tuning), pluggable
//!   into both the single-job engine and the multi-user simulator.

pub mod asm;
pub mod controller;
pub mod monitor;

pub use asm::{Asm, AsmDecision, AsmPhase};
pub use controller::DynamicTuner;
pub use monitor::{AlarmLevel, DeviationMonitor};
