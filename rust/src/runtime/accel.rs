//! PJRT-accelerated offline backends: the AOT-compiled JAX/Pallas
//! pipeline behind the same traits the native math implements.
//!
//! * [`PjrtSurfaceBackend`] — batched bicubic fit + dense refinement +
//!   stats through the `surface_pipeline` artifact (L2 graph calling
//!   the L1 Pallas kernel);
//! * [`PjrtKmeans`] — Lloyd assignment through the `kmeans_step`
//!   artifact (Pallas pairwise-distance kernel); the trivial centroid
//!   arithmetic is redone natively so arbitrary N (the artifact shape
//!   is fixed at 2048×8) can be chunked without bias.
//!
//! Both pad to the manifest's static shapes and are parity-tested
//! against the native backends in `rust/tests/integration_runtime.rs`.

use crate::offline::features::N_FEATURES;
use crate::offline::kmeans::KmeansBackend;
use crate::offline::spline::BicubicSurface;
use crate::offline::surface::{FittedSurface, NativeSurfaceBackend, SurfaceBackend};
use crate::runtime::engine::Engine;
use crate::util::stats;

/// Surface backend running the `surface_pipeline` artifact.
pub struct PjrtSurfaceBackend {
    pub engine: Engine,
}

impl PjrtSurfaceBackend {
    pub fn new(engine: Engine) -> PjrtSurfaceBackend {
        PjrtSurfaceBackend { engine }
    }

    fn consts(&self) -> (usize, usize, usize, usize) {
        let m = &self.engine.manifest;
        (
            m.konst("S").unwrap_or(16),
            m.konst("GP").unwrap_or(8),
            m.konst("GC").unwrap_or(8),
            m.konst("RF").unwrap_or(8),
        )
    }
}

impl SurfaceBackend for PjrtSurfaceBackend {
    fn fit_batch(
        &self,
        xs: &[f64],
        ys: &[f64],
        values: &[Vec<Vec<f64>>],
        rf: usize,
    ) -> Vec<FittedSurface> {
        let (s_max, gp, gc, rf_art) = self.consts();
        // shape family mismatch -> native fallback (correctness first)
        if xs.len() != gp || ys.len() != gc || rf != rf_art || values.is_empty() {
            return NativeSurfaceBackend.fit_batch(xs, ys, values, rf);
        }

        let xs32: Vec<f32> = xs.iter().map(|&v| v as f32).collect();
        let ys32: Vec<f32> = ys.iter().map(|&v| v as f32).collect();

        let mut out = Vec::with_capacity(values.len());
        for chunk in values.chunks(s_max) {
            // pad the batch by repeating the first grid
            let mut flat = Vec::with_capacity(s_max * gp * gc);
            for grid in chunk.iter().chain(std::iter::repeat(&chunk[0])).take(s_max) {
                for row in grid {
                    for &v in row {
                        flat.push(v as f32);
                    }
                }
            }
            let res = match self.engine.surface_pipeline(&xs32, &ys32, &flat) {
                Ok(r) => r,
                Err(err) => {
                    eprintln!("warning: surface_pipeline failed ({err:#}); native fallback");
                    return NativeSurfaceBackend.fit_batch(xs, ys, values, rf);
                }
            };
            let stride_c = (gp - 1) * (gc - 1) * 16;
            let dw = (gc - 1) * rf; // dense width
            let stride_d = (gp - 1) * rf * dw;
            for (si, grid) in chunk.iter().enumerate() {
                // rebuild the surface from the artifact's coefficients
                let cslice = &res.coeffs[si * stride_c..(si + 1) * stride_c];
                let mut coeffs = vec![vec![[0.0f64; 16]; gc - 1]; gp - 1];
                for i in 0..gp - 1 {
                    for j in 0..gc - 1 {
                        for k in 0..16 {
                            coeffs[i][j][k] =
                                cslice[(i * (gc - 1) + j) * 16 + k] as f64;
                        }
                    }
                }
                let surface = BicubicSurface {
                    xs: xs.to_vec(),
                    ys: ys.to_vec(),
                    coeffs,
                };
                // argmax: dense winner, folded with the knot grid (same
                // logic as the native backend)
                let mut max_v = res.maxv[si] as f64;
                let (ai, aj) = (
                    res.argmax[si * 2] as usize,
                    res.argmax[si * 2 + 1] as usize,
                );
                let dense_max = res.dense[si * stride_d + ai * dw + aj] as f64;
                let mut max_at = surface.refined_to_coords(ai, aj, rf);
                if max_v > dense_max + 1e-9 {
                    // a knot value beat the refinement: locate it
                    for (i, row) in grid.iter().enumerate() {
                        for (j, &v) in row.iter().enumerate() {
                            if v >= max_v - 1e-6 {
                                max_at = (xs[i], ys[j]);
                                max_v = max_v.max(v);
                            }
                        }
                    }
                }
                out.push(FittedSurface {
                    surface,
                    max_th: max_v,
                    max_at,
                    grid_mean: res.mean[si] as f64,
                    grid_std: res.std[si] as f64,
                });
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

/// K-means backend running the `kmeans_step` artifact for assignment.
pub struct PjrtKmeans {
    pub engine: Engine,
}

impl PjrtKmeans {
    pub fn new(engine: Engine) -> PjrtKmeans {
        PjrtKmeans { engine }
    }
}

impl KmeansBackend for PjrtKmeans {
    fn step(
        &self,
        points: &[[f64; N_FEATURES]],
        centroids: &[[f64; N_FEATURES]],
    ) -> (Vec<[f64; N_FEATURES]>, Vec<usize>, f64) {
        let m = &self.engine.manifest;
        let (n_art, d_art, k_art) = (
            m.konst("N").unwrap_or(2048),
            m.konst("D").unwrap_or(8),
            m.konst("K").unwrap_or(16),
        );
        let k = centroids.len();
        if k > k_art || N_FEATURES > d_art || points.is_empty() {
            return crate::offline::kmeans::NativeKmeans.step(points, centroids);
        }

        // pad centroids: unused slots parked far away so no point
        // chooses them
        let mut c32 = vec![0.0f32; k_art * d_art];
        for (ki, c) in centroids.iter().enumerate() {
            for f in 0..N_FEATURES {
                c32[ki * d_art + f] = c[f] as f32;
            }
        }
        for ki in k..k_art {
            c32[ki * d_art] = 1e9;
        }

        let mut assignment = vec![0usize; points.len()];
        for (ci, chunk) in points.chunks(n_art).enumerate() {
            // pad the tail chunk by repeating the first point; padded
            // assignments are discarded
            let mut x32 = vec![0.0f32; n_art * d_art];
            for (pi, p) in chunk
                .iter()
                .chain(std::iter::repeat(&chunk[0]))
                .take(n_art)
                .enumerate()
            {
                for f in 0..N_FEATURES {
                    x32[pi * d_art + f] = p[f] as f32;
                }
            }
            match self.engine.kmeans_step(&x32, &c32) {
                Ok(res) => {
                    for (pi, _) in chunk.iter().enumerate() {
                        assignment[ci * n_art + pi] = res.assign[pi] as usize;
                    }
                }
                Err(err) => {
                    eprintln!("warning: kmeans_step failed ({err:#}); native fallback");
                    return crate::offline::kmeans::NativeKmeans.step(points, centroids);
                }
            }
        }

        // centroid update + inertia natively (exact, unbiased by padding)
        let mut sums = vec![[0.0; N_FEATURES]; k];
        let mut counts = vec![0usize; k];
        let mut d2s = vec![0.0f64; points.len()];
        let mut inertia = 0.0;
        for (pi, (p, &a)) in points.iter().zip(&assignment).enumerate() {
            let a = a.min(k - 1);
            counts[a] += 1;
            let mut d2 = 0.0;
            for f in 0..N_FEATURES {
                sums[a][f] += p[f];
                let d = p[f] - centroids[a][f];
                d2 += d * d;
            }
            d2s[pi] = d2;
            inertia += d2;
        }
        let mut new_centroids: Vec<[f64; N_FEATURES]> = (0..k)
            .map(|ki| {
                if counts[ki] == 0 {
                    centroids[ki]
                } else {
                    let mut c = [0.0; N_FEATURES];
                    for f in 0..N_FEATURES {
                        c[f] = sums[ki][f] / counts[ki] as f64;
                    }
                    c
                }
            })
            .collect();
        // same dead-cluster repair as the native backend (parity)
        crate::offline::kmeans::reseed_empty_clusters(points, &d2s, &counts, &mut new_centroids);
        (new_centroids, assignment, inertia)
    }
}

/// Quick sanity statistic used by perf logging: mean |a-b| over slices.
pub fn mean_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let diffs: Vec<f64> = a.iter().zip(b).map(|(x, y)| (x - y).abs()).collect();
    stats::mean(&diffs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offline::surface::knot_lattice;
    use crate::util::rng::Rng;

    fn pjrt_surface() -> Option<PjrtSurfaceBackend> {
        Engine::try_default().map(PjrtSurfaceBackend::new)
    }

    #[test]
    fn pjrt_surface_matches_native() {
        let Some(backend) = pjrt_surface() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let xs = knot_lattice();
        let mut rng = Rng::new(3);
        let grids: Vec<Vec<Vec<f64>>> = (0..3)
            .map(|_| {
                (0..xs.len())
                    .map(|_| (0..xs.len()).map(|_| rng.uniform(50.0, 1_000.0)).collect())
                    .collect()
            })
            .collect();
        let pjrt = backend.fit_batch(&xs, &xs, &grids, 8);
        let native = NativeSurfaceBackend.fit_batch(&xs, &xs, &grids, 8);
        assert_eq!(pjrt.len(), native.len());
        for (p, n) in pjrt.iter().zip(&native) {
            // f32 artifact vs f64 native: allow small drift
            assert!(
                (p.max_th - n.max_th).abs() / n.max_th < 1e-3,
                "max {} vs {}",
                p.max_th,
                n.max_th
            );
            assert!((p.grid_mean - n.grid_mean).abs() / n.grid_mean < 1e-4);
            // surfaces agree pointwise
            for pq in [1.5f64, 4.0, 11.0, 27.0] {
                for cq in [1.0f64, 6.5, 19.0, 32.0] {
                    let a = p.surface.eval(pq, cq);
                    let b = n.surface.eval(pq, cq);
                    assert!(
                        (a - b).abs() < 1e-2 * b.abs().max(1.0),
                        "eval({pq},{cq}): {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn pjrt_kmeans_matches_native() {
        let Some(e) = Engine::try_default() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let backend = PjrtKmeans::new(e);
        let mut rng = Rng::new(4);
        let mut points = Vec::new();
        for c in [[0.0; N_FEATURES], [8.0; N_FEATURES]] {
            for _ in 0..700 {
                let mut p = c;
                for f in p.iter_mut() {
                    *f += rng.normal() * 0.3;
                }
                points.push(p);
            }
        }
        let centroids = vec![[0.5; N_FEATURES], [7.5; N_FEATURES]];
        let (pc, pa, pi) = backend.step(&points, &centroids);
        let (nc, na, ni) =
            crate::offline::kmeans::NativeKmeans.step(&points, &centroids);
        assert_eq!(pa, na);
        assert!((pi - ni).abs() / ni < 1e-6);
        for (a, b) in pc.iter().zip(&nc) {
            for f in 0..N_FEATURES {
                assert!((a[f] - b[f]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn mean_abs_diff_basics() {
        assert_eq!(mean_abs_diff(&[], &[]), 0.0);
        assert!((mean_abs_diff(&[1.0, 2.0], &[2.0, 0.0]) - 1.5).abs() < 1e-12);
    }
}
