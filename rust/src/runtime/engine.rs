//! The PJRT engine: load HLO-text artifacts, compile once, execute many.
//!
//! Follows the /opt/xla-example pattern: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Every artifact was lowered with `return_tuple=True`, so outputs come
//! back as one tuple literal that we decompose.
//!
//! The XLA bindings are only available in images that ship the vendored
//! `xla` crate, so the real implementation is gated behind the `pjrt`
//! cargo feature. Without it this module compiles a stub [`Engine`]
//! with the same public surface whose loaders report the runtime as
//! unavailable — every caller already falls back to native math when
//! `try_default()` returns `None`, so plain-toolchain builds work from
//! a clean checkout.

use crate::runtime::manifest::Manifest;
use crate::util::err::Result;
use std::path::Path;

/// Outputs of the `surface_pipeline` artifact (all row-major f32).
#[derive(Debug, Clone)]
pub struct SurfacePipelineOut {
    /// [S, GP-1, GC-1, 16]
    pub coeffs: Vec<f32>,
    /// [S, (GP-1)*RF, (GC-1)*RF]
    pub dense: Vec<f32>,
    /// [S]
    pub maxv: Vec<f32>,
    /// [S, 2] refined-grid argmax (i, j) as f32
    pub argmax: Vec<f32>,
    /// [S]
    pub mean: Vec<f32>,
    /// [S]
    pub std: Vec<f32>,
}

/// Outputs of the `kmeans_step` artifact.
#[derive(Debug, Clone)]
pub struct KmeansStepOut {
    /// [K, D]
    pub new_centroids: Vec<f32>,
    /// [N] assignment as f32
    pub assign: Vec<f32>,
    pub inertia: f32,
}

/// Compiled-artifact registry over one PJRT client.
#[cfg(feature = "pjrt")]
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    executables: std::collections::BTreeMap<String, xla::PjRtLoadedExecutable>,
}

#[cfg(feature = "pjrt")]
impl Engine {
    /// Load and compile every artifact in `dir`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        use crate::util::err::Context;
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let mut executables = std::collections::BTreeMap::new();
        for (name, meta) in &manifest.artifacts {
            let proto = xla::HloModuleProto::from_text_file(
                meta.file
                    .to_str()
                    .context("artifact path is not valid UTF-8")?,
            )
            .with_context(|| format!("parsing HLO text for {name}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {name}"))?;
            executables.insert(name.clone(), exe);
        }
        Ok(Engine {
            client,
            manifest,
            executables,
        })
    }

    /// Load from the default artifact directory; None when artifacts
    /// have not been built (callers fall back to native math).
    pub fn try_default() -> Option<Engine> {
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            return None;
        }
        match Engine::load(&dir) {
            Ok(e) => Some(e),
            Err(err) => {
                eprintln!("warning: PJRT engine unavailable ({err:#}); using native math");
                None
            }
        }
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        use crate::bail;
        use crate::util::err::Context;
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("artifact {name} not compiled"))?;
        let meta = self.manifest.artifact(name)?;
        if inputs.len() != meta.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                meta.inputs.len(),
                inputs.len()
            );
        }
        for (i, (lit, shape)) in inputs.iter().zip(&meta.inputs).enumerate() {
            let expect: usize = shape.iter().product();
            if lit.element_count() != expect {
                bail!(
                    "{name}: input {i} has {} elements, manifest wants {:?}",
                    lit.element_count(),
                    shape
                );
            }
        }
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {name}"))?[0][0]
            .to_literal_sync()?;
        let parts = result.to_tuple().context("decomposing output tuple")?;
        if parts.len() != meta.outputs.len() {
            bail!(
                "{name}: got {} outputs, manifest wants {}",
                parts.len(),
                meta.outputs.len()
            );
        }
        Ok(parts)
    }

    fn lit_f32(data: &[f32], shape: &[usize]) -> Result<xla::Literal> {
        let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&dims)?)
    }

    /// Run the fused fit + dense-refine + stats pipeline on a batch of
    /// S value grids sharing knots.
    pub fn surface_pipeline(
        &self,
        xs: &[f32],
        ys: &[f32],
        values: &[f32],
    ) -> Result<SurfacePipelineOut> {
        let meta = self.manifest.artifact("surface_pipeline")?;
        let (gp, gc) = (meta.inputs[0][0], meta.inputs[1][0]);
        let s = meta.inputs[2][0];
        let inputs = [
            Self::lit_f32(xs, &[gp])?,
            Self::lit_f32(ys, &[gc])?,
            Self::lit_f32(values, &[s, gp, gc])?,
        ];
        let parts = self.run("surface_pipeline", &inputs)?;
        Ok(SurfacePipelineOut {
            coeffs: parts[0].to_vec::<f32>()?,
            dense: parts[1].to_vec::<f32>()?,
            maxv: parts[2].to_vec::<f32>()?,
            argmax: parts[3].to_vec::<f32>()?,
            mean: parts[4].to_vec::<f32>()?,
            std: parts[5].to_vec::<f32>()?,
        })
    }

    /// One Lloyd iteration over padded [N, D] points and [K, D]
    /// centroids.
    pub fn kmeans_step(&self, x: &[f32], c: &[f32]) -> Result<KmeansStepOut> {
        let meta = self.manifest.artifact("kmeans_step")?;
        let (n, d) = (meta.inputs[0][0], meta.inputs[0][1]);
        let k = meta.inputs[1][0];
        let inputs = [Self::lit_f32(x, &[n, d])?, Self::lit_f32(c, &[k, d])?];
        let parts = self.run("kmeans_step", &inputs)?;
        Ok(KmeansStepOut {
            new_centroids: parts[0].to_vec::<f32>()?,
            assign: parts[1].to_vec::<f32>()?,
            inertia: parts[2].to_vec::<f32>()?[0],
        })
    }
}

/// Stub engine for builds without the `pjrt` feature: the manifest
/// still parses (so `twophase info` can report artifact status) but
/// nothing compiles or executes.
#[cfg(not(feature = "pjrt"))]
pub struct Engine {
    pub manifest: Manifest,
}

#[cfg(not(feature = "pjrt"))]
impl Engine {
    /// Always an error without the `pjrt` feature.
    pub fn load(dir: impl AsRef<Path>) -> Result<Engine> {
        let _ = Manifest::load(&dir)?;
        crate::bail!("built without the `pjrt` feature; PJRT execution is unavailable")
    }

    /// Always `None` without the `pjrt` feature; callers fall back to
    /// native math.
    pub fn try_default() -> Option<Engine> {
        None
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn surface_pipeline(
        &self,
        _xs: &[f32],
        _ys: &[f32],
        _values: &[f32],
    ) -> Result<SurfacePipelineOut> {
        crate::bail!("built without the `pjrt` feature; PJRT execution is unavailable")
    }

    pub fn kmeans_step(&self, _x: &[f32], _c: &[f32]) -> Result<KmeansStepOut> {
        crate::bail!("built without the `pjrt` feature; PJRT execution is unavailable")
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod stub_tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        assert!(Engine::try_default().is_none());
        let e = Engine::load("/definitely/not/a/dir").unwrap_err();
        assert!(!e.to_string().is_empty());
    }
}

#[cfg(all(test, feature = "pjrt"))]
mod tests {
    use super::*;

    /// These tests exercise the real artifacts when `make artifacts`
    /// has run; they are skipped (not failed) otherwise so `cargo test`
    /// works from a clean checkout.
    fn engine() -> Option<Engine> {
        Engine::try_default()
    }

    #[test]
    fn loads_and_compiles_all_artifacts() {
        let Some(e) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        assert_eq!(e.platform(), "cpu");
        assert!(e.executables.len() >= 3);
    }

    #[test]
    fn surface_pipeline_shapes() {
        let Some(e) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = &e.manifest;
        let (s, gp, gc, rf) = (
            m.konst("S").unwrap(),
            m.konst("GP").unwrap(),
            m.konst("GC").unwrap(),
            m.konst("RF").unwrap(),
        );
        let xs: Vec<f32> = (0..gp).map(|i| (i + 1) as f32).collect();
        let ys: Vec<f32> = (0..gc).map(|i| (i + 1) as f32).collect();
        let values: Vec<f32> = (0..s * gp * gc).map(|i| (i % 97) as f32).collect();
        let out = e.surface_pipeline(&xs, &ys, &values).unwrap();
        assert_eq!(out.coeffs.len(), s * (gp - 1) * (gc - 1) * 16);
        assert_eq!(out.dense.len(), s * (gp - 1) * rf * (gc - 1) * rf);
        assert_eq!(out.maxv.len(), s);
        assert_eq!(out.argmax.len(), s * 2);
        assert_eq!(out.mean.len(), s);
        assert_eq!(out.std.len(), s);
    }

    #[test]
    fn input_shape_mismatch_is_error() {
        let Some(e) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let bad = e.surface_pipeline(&[1.0; 3], &[1.0; 8], &[0.0; 16 * 8 * 8]);
        assert!(bad.is_err());
    }

    #[test]
    fn kmeans_step_assigns_to_nearest() {
        let Some(e) = engine() else {
            eprintln!("skipping: artifacts not built");
            return;
        };
        let m = &e.manifest;
        let (n, d, k) = (
            m.konst("N").unwrap(),
            m.konst("D").unwrap(),
            m.konst("K").unwrap(),
        );
        // half the points at 0, half at 10 (first feature)
        let mut x = vec![0.0f32; n * d];
        for i in n / 2..n {
            x[i * d] = 10.0;
        }
        let mut c = vec![0.0f32; k * d];
        c[0] = 1.0; // centroid 0 near the zeros
        c[d] = 9.0; // centroid 1 near the tens
        for kk in 2..k {
            c[kk * d] = 1e6; // park the rest far away
        }
        let out = e.kmeans_step(&x, &c).unwrap();
        assert!(out.assign[..n / 2].iter().all(|&a| a == 0.0));
        assert!(out.assign[n / 2..].iter().all(|&a| a == 1.0));
        // updated centroids move onto the data
        assert!((out.new_centroids[0] - 0.0).abs() < 1e-4);
        assert!((out.new_centroids[d] - 10.0).abs() < 1e-4);
        assert!(out.inertia > 0.0);
    }
}
