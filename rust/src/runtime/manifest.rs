//! `artifacts/manifest.json` parsing: artifact names, files and the
//! static shape family the AOT path fixed (S, GP, GC, RF, N, D, K).

use crate::bail;
use crate::util::err::{Context, Result};
use crate::util::json::Value;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One artifact's metadata.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<Vec<usize>>,
    pub outputs: Vec<Vec<usize>>,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub consts: BTreeMap<String, usize>,
    pub artifacts: BTreeMap<String, ArtifactMeta>,
}

fn shape_list(v: &Value) -> Result<Vec<Vec<usize>>> {
    let arr = v.as_arr().context("expected shape list")?;
    arr.iter()
        .map(|s| {
            s.as_arr()
                .context("expected shape")?
                .iter()
                .map(|d| d.as_u64().map(|x| x as usize).context("bad dim"))
                .collect()
        })
        .collect()
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = Value::parse(&text).context("parsing manifest.json")?;
        if v.get("format").as_str() != Some("hlo-text") {
            bail!("unsupported artifact format {:?}", v.get("format"));
        }
        let mut consts = BTreeMap::new();
        for (k, val) in v.get("consts").as_obj().context("consts")? {
            consts.insert(
                k.clone(),
                val.as_u64().context("const must be integer")? as usize,
            );
        }
        let mut artifacts = BTreeMap::new();
        for (name, meta) in v.get("artifacts").as_obj().context("artifacts")? {
            artifacts.insert(
                name.clone(),
                ArtifactMeta {
                    name: name.clone(),
                    file: dir.join(meta.get("file").as_str().context("file")?),
                    inputs: shape_list(meta.get("inputs"))?,
                    outputs: shape_list(meta.get("outputs"))?,
                },
            );
        }
        Ok(Manifest {
            dir,
            consts,
            artifacts,
        })
    }

    /// Default artifact dir: `$TWOPHASE_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("TWOPHASE_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn konst(&self, name: &str) -> Result<usize> {
        self.consts
            .get(name)
            .copied()
            .with_context(|| format!("manifest const {name} missing"))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactMeta> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name} missing from manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        let mut f = std::fs::File::create(dir.join("manifest.json")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    #[test]
    fn parses_valid_manifest() {
        let dir = std::env::temp_dir().join(format!("tp-manifest-{}", std::process::id()));
        write_manifest(
            &dir,
            r#"{"format":"hlo-text","consts":{"S":16,"GP":8},
                "artifacts":{"surface_fit":{"file":"surface_fit.hlo.txt",
                "inputs":[[8],[8],[16,8,8]],"outputs":[[16,7,7,16]]}}}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.konst("S").unwrap(), 16);
        let a = m.artifact("surface_fit").unwrap();
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.outputs[0], vec![16, 7, 7, 16]);
        assert!(a.file.ends_with("surface_fit.hlo.txt"));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_wrong_format() {
        let dir = std::env::temp_dir().join(format!("tp-manifest-bad-{}", std::process::id()));
        write_manifest(&dir, r#"{"format":"proto","consts":{},"artifacts":{}}"#);
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_lookups_error() {
        let dir = std::env::temp_dir().join(format!("tp-manifest-miss-{}", std::process::id()));
        write_manifest(&dir, r#"{"format":"hlo-text","consts":{},"artifacts":{}}"#);
        let m = Manifest::load(&dir).unwrap();
        assert!(m.konst("S").is_err());
        assert!(m.artifact("nope").is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn real_artifacts_manifest_if_present() {
        // when `make artifacts` has run, validate the real manifest
        let dir = Manifest::default_dir();
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            for name in ["surface_fit", "surface_pipeline", "kmeans_step"] {
                let a = m.artifact(name).unwrap();
                assert!(a.file.exists(), "{} missing", a.file.display());
            }
            assert_eq!(m.konst("GP").unwrap(), 8);
        }
    }
}
