//! PJRT execution of the AOT artifacts built by `python/compile/aot.py`.
//!
//! Python runs once at build time; at run time the Rust binary loads
//! the HLO-*text* artifacts (`artifacts/*.hlo.txt`), compiles them on
//! the PJRT CPU client via the `xla` crate, and executes them on the
//! offline-analysis hot path.  [`accel`] adapts the compiled
//! executables to the [`crate::offline::surface::SurfaceBackend`] and
//! [`crate::offline::kmeans::KmeansBackend`] traits, with the native
//! Rust math as the parity-tested fallback when artifacts are absent.

pub mod accel;
pub mod engine;
pub mod manifest;

pub use accel::{PjrtKmeans, PjrtSurfaceBackend};
pub use engine::Engine;
pub use manifest::Manifest;
