//! Small dense linear algebra: Thomas tridiagonal solver (natural-spline
//! systems), partial-pivot LU (regression normal equations), and
//! least-squares fitting.  Matrices are row-major `Vec<f64>`.

/// Solve a tridiagonal system in O(n).
///
/// `sub[i]` multiplies x[i-1] in row i (sub[0] ignored), `diag[i]` x[i],
/// `sup[i]` x[i+1] (sup[n-1] ignored).  Panics on size mismatch,
/// returns None when a pivot collapses.
pub fn thomas(sub: &[f64], diag: &[f64], sup: &[f64], rhs: &[f64]) -> Option<Vec<f64>> {
    let n = diag.len();
    assert!(sub.len() == n && sup.len() == n && rhs.len() == n);
    if n == 0 {
        return Some(vec![]);
    }
    let mut cp = vec![0.0; n];
    let mut dp = vec![0.0; n];
    if diag[0].abs() < 1e-300 {
        return None;
    }
    cp[0] = sup[0] / diag[0];
    dp[0] = rhs[0] / diag[0];
    for i in 1..n {
        let denom = diag[i] - sub[i] * cp[i - 1];
        if denom.abs() < 1e-300 {
            return None;
        }
        cp[i] = sup[i] / denom;
        dp[i] = (rhs[i] - sub[i] * dp[i - 1]) / denom;
    }
    let mut x = vec![0.0; n];
    x[n - 1] = dp[n - 1];
    for i in (0..n - 1).rev() {
        x[i] = dp[i] - cp[i] * x[i + 1];
    }
    Some(x)
}

/// Row-major dense matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Mat {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f64) {
        self.data[r * self.cols + c] = v;
    }

    /// A^T * A (for normal equations).
    pub fn gram(&self) -> Mat {
        let mut g = Mat::zeros(self.cols, self.cols);
        for i in 0..self.cols {
            for j in i..self.cols {
                let mut s = 0.0;
                for r in 0..self.rows {
                    s += self.at(r, i) * self.at(r, j);
                }
                g.set(i, j, s);
                g.set(j, i, s);
            }
        }
        g
    }

    /// A^T * b.
    pub fn t_vec(&self, b: &[f64]) -> Vec<f64> {
        assert_eq!(b.len(), self.rows);
        let mut out = vec![0.0; self.cols];
        for r in 0..self.rows {
            for c in 0..self.cols {
                out[c] += self.at(r, c) * b[r];
            }
        }
        out
    }

    /// A * x.
    pub fn mul_vec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        let mut out = vec![0.0; self.rows];
        for r in 0..self.rows {
            let mut s = 0.0;
            for c in 0..self.cols {
                s += self.at(r, c) * x[c];
            }
            out[r] = s;
        }
        out
    }
}

/// Solve A x = b by partial-pivot LU.  None if singular.
pub fn lu_solve(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    assert_eq!(a.rows, a.cols, "lu_solve needs a square matrix");
    assert_eq!(b.len(), a.rows);
    let n = a.rows;
    let mut m = a.data.clone();
    let mut x = b.to_vec();
    for col in 0..n {
        // pivot
        let mut piv = col;
        let mut best = m[col * n + col].abs();
        for r in col + 1..n {
            let v = m[r * n + col].abs();
            if v > best {
                best = v;
                piv = r;
            }
        }
        if best < 1e-12 {
            return None;
        }
        if piv != col {
            for c in 0..n {
                m.swap(col * n + c, piv * n + c);
            }
            x.swap(col, piv);
        }
        let d = m[col * n + col];
        for r in col + 1..n {
            let f = m[r * n + col] / d;
            if f == 0.0 {
                continue;
            }
            for c in col..n {
                m[r * n + c] -= f * m[col * n + c];
            }
            x[r] -= f * x[col];
        }
    }
    // back substitution
    for r in (0..n).rev() {
        let mut s = x[r];
        for c in r + 1..n {
            s -= m[r * n + c] * x[c];
        }
        x[r] = s / m[r * n + r];
    }
    Some(x)
}

/// Least squares: minimize ||A x - b||² via ridge-stabilized normal
/// equations (tiny λ keeps rank-deficient design matrices solvable).
pub fn least_squares(a: &Mat, b: &[f64]) -> Option<Vec<f64>> {
    let mut g = a.gram();
    let lambda = 1e-12
        * (0..g.rows)
            .map(|i| g.at(i, i))
            .fold(0.0, f64::max)
            .max(1e-12);
    for i in 0..g.rows {
        let v = g.at(i, i) + lambda;
        g.set(i, i, v);
    }
    let atb = a.t_vec(b);
    lu_solve(&g, &atb)
}

/// 2x2 symmetric eigenvalues (for the Hessian definiteness test).
pub fn sym2_eigenvalues(a: f64, b: f64, d: f64) -> (f64, f64) {
    // matrix [[a, b], [b, d]]
    let tr = a + d;
    let det = a * d - b * b;
    let disc = (tr * tr / 4.0 - det).max(0.0).sqrt();
    (tr / 2.0 - disc, tr / 2.0 + disc)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: &[f64], b: &[f64], tol: f64) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < tol, "{a:?} vs {b:?}");
        }
    }

    #[test]
    fn thomas_known_system() {
        // [[2,1,0],[1,2,1],[0,1,2]] x = [4,8,8] -> x = [1,2,3]
        let x = thomas(
            &[0.0, 1.0, 1.0],
            &[2.0, 2.0, 2.0],
            &[1.0, 1.0, 0.0],
            &[4.0, 8.0, 8.0],
        )
        .unwrap();
        close(&x, &[1.0, 2.0, 3.0], 1e-12);
    }

    #[test]
    fn thomas_size_one_and_empty() {
        close(
            &thomas(&[0.0], &[4.0], &[0.0], &[8.0]).unwrap(),
            &[2.0],
            1e-12,
        );
        assert_eq!(thomas(&[], &[], &[], &[]).unwrap(), Vec::<f64>::new());
    }

    #[test]
    fn thomas_singular_is_none() {
        assert!(thomas(&[0.0], &[0.0], &[0.0], &[1.0]).is_none());
    }

    #[test]
    fn lu_solves_random_system() {
        let a = Mat::from_rows(&[
            vec![4.0, -2.0, 1.0],
            vec![3.0, 6.0, -4.0],
            vec![2.0, 1.0, 8.0],
        ]);
        let x_true = [1.0, -2.0, 0.5];
        let b = a.mul_vec(&x_true);
        let x = lu_solve(&a, &b).unwrap();
        close(&x, &x_true, 1e-10);
    }

    #[test]
    fn lu_needs_pivoting() {
        let a = Mat::from_rows(&[vec![0.0, 1.0], vec![1.0, 0.0]]);
        let x = lu_solve(&a, &[3.0, 7.0]).unwrap();
        close(&x, &[7.0, 3.0], 1e-12);
    }

    #[test]
    fn lu_singular_is_none() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 4.0]]);
        assert!(lu_solve(&a, &[1.0, 2.0]).is_none());
    }

    #[test]
    fn least_squares_recovers_line() {
        // y = 3 + 2x fitted from noisy-free samples
        let xs = [0.0, 1.0, 2.0, 3.0, 4.0];
        let rows: Vec<Vec<f64>> = xs.iter().map(|&x| vec![1.0, x]).collect();
        let a = Mat::from_rows(&rows);
        let b: Vec<f64> = xs.iter().map(|&x| 3.0 + 2.0 * x).collect();
        let c = least_squares(&a, &b).unwrap();
        close(&c, &[3.0, 2.0], 1e-6);
    }

    #[test]
    fn least_squares_overdetermined() {
        // quadratic through >3 points
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let rows: Vec<Vec<f64>> = xs.iter().map(|&x| vec![1.0, x, x * x]).collect();
        let a = Mat::from_rows(&rows);
        let b: Vec<f64> = xs.iter().map(|&x| 1.0 - x + 0.5 * x * x).collect();
        let c = least_squares(&a, &b).unwrap();
        close(&c, &[1.0, -1.0, 0.5], 1e-6);
    }

    #[test]
    fn sym2_eigs() {
        let (lo, hi) = sym2_eigenvalues(2.0, 0.0, 3.0);
        assert!((lo - 2.0).abs() < 1e-12 && (hi - 3.0).abs() < 1e-12);
        // negative definite
        let (lo, hi) = sym2_eigenvalues(-2.0, 1.0, -2.0);
        assert!(lo < 0.0 && hi < 0.0);
    }

    #[test]
    fn gram_symmetry() {
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let g = a.gram();
        assert_eq!(g.at(0, 1), g.at(1, 0));
        assert!((g.at(0, 0) - 35.0).abs() < 1e-12);
    }
}
