//! Tiny CLI argument parser (clap is unavailable offline — DESIGN.md §4).
//!
//! Grammar: `binary <subcommand> [--flag] [--key value] [positional...]`.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if let Some(v) = iter.next_if(|n| !n.starts_with("--")) {
                    out.flags.insert(name.to_string(), v);
                } else {
                    out.flags.insert(name.to_string(), "true".to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the process's own arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).map(|v| v != "false").unwrap_or(false)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .and_then(|v| v.parse().ok())
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse("transfer dataset1 dataset2");
        assert_eq!(a.subcommand.as_deref(), Some("transfer"));
        assert_eq!(a.positional, vec!["dataset1", "dataset2"]);
    }

    #[test]
    fn key_value_both_styles() {
        let a = parse("run --seed 42 --profile=xsede");
        assert_eq!(a.get_u64("seed", 0), 42);
        assert_eq!(a.get("profile"), Some("xsede"));
    }

    #[test]
    fn boolean_flags() {
        let a = parse("run --verbose --out file.json");
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.get("out"), Some("file.json"));
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse("run --dry-run");
        assert!(a.flag("dry-run"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse("run --alpha 0.5");
        assert_eq!(a.get_f64("alpha", 1.0), 0.5);
        assert_eq!(a.get_f64("beta", 2.0), 2.0);
        assert_eq!(a.get_usize("n", 7), 7);
    }
}
