//! Descriptive statistics used across the offline phase (Gaussian
//! confidence regions), the monitors (EWMA) and the experiment
//! harnesses (percentiles, Jain fairness index).

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation (Eq 14 of the paper uses 1/N).
pub fn std_pop(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Sample standard deviation (1/(N-1)).
pub fn std_sample(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated percentile, q in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = (q / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let w = rank - lo as f64;
        v[lo] * (1.0 - w) + v[hi] * w
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Jain's fairness index: (Σx)² / (n·Σx²) ∈ (0, 1]; 1 = perfectly fair.
/// Used for the §5.4 multi-user fairness analysis.
pub fn jain_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let s: f64 = xs.iter().sum();
    let s2: f64 = xs.iter().map(|x| x * x).sum();
    if s2 == 0.0 {
        return 1.0;
    }
    s * s / (xs.len() as f64 * s2)
}

/// Exponentially-weighted moving average with deviation tracking — the
/// online monitor's persistent-change detector builds on this.
#[derive(Debug, Clone)]
pub struct Ewma {
    alpha: f64,
    value: Option<f64>,
    /// EWMA of |sample - value| (mean absolute deviation).
    dev: f64,
}

impl Ewma {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha));
        Ewma {
            alpha,
            value: None,
            dev: 0.0,
        }
    }

    pub fn update(&mut self, sample: f64) -> f64 {
        match self.value {
            None => {
                self.value = Some(sample);
                sample
            }
            Some(v) => {
                self.dev = (1.0 - self.alpha) * self.dev + self.alpha * (sample - v).abs();
                let nv = (1.0 - self.alpha) * v + self.alpha * sample;
                self.value = Some(nv);
                nv
            }
        }
    }

    pub fn value(&self) -> Option<f64> {
        self.value
    }

    pub fn deviation(&self) -> f64 {
        self.dev
    }

    pub fn reset(&mut self) {
        self.value = None;
        self.dev = 0.0;
    }
}

/// Equal-width histogram over [lo, hi] — Fig 4(a) needs one.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    assert!(bins > 0 && hi > lo);
    let mut counts = vec![0usize; bins];
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        if x < lo || x > hi {
            continue;
        }
        let b = (((x - lo) / w) as usize).min(bins - 1);
        counts[b] += 1;
    }
    counts
}

/// Gaussian pdf (Eq 12).
pub fn gaussian_pdf(x: f64, mu: f64, sigma: f64) -> f64 {
    if sigma <= 0.0 {
        return if (x - mu).abs() < 1e-12 { f64::INFINITY } else { 0.0 };
    }
    let z = (x - mu) / sigma;
    (-0.5 * z * z).exp() / (sigma * (2.0 * std::f64::consts::PI).sqrt())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_pop(&xs) - 2.0).abs() < 1e-12);
        assert!(std_sample(&xs) > std_pop(&xs));
    }

    #[test]
    fn empty_slices_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_pop(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(jain_index(&[]), 1.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(median(&xs), 3.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn jain() {
        assert!((jain_index(&[5.0, 5.0, 5.0]) - 1.0).abs() < 1e-12);
        // one user hogging everything among 4 -> 1/4
        assert!((jain_index(&[1.0, 0.0, 0.0, 0.0]) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn ewma_converges() {
        let mut e = Ewma::new(0.3);
        for _ in 0..100 {
            e.update(10.0);
        }
        assert!((e.value().unwrap() - 10.0).abs() < 1e-6);
        assert!(e.deviation() < 1e-6);
    }

    #[test]
    fn ewma_deviation_reflects_noise() {
        let mut e = Ewma::new(0.2);
        let mut flip = 1.0;
        for _ in 0..200 {
            e.update(10.0 + flip);
            flip = -flip;
        }
        assert!(e.deviation() > 0.5);
    }

    #[test]
    fn histogram_bins() {
        let xs = [0.1, 0.2, 0.9, 0.55, 2.0];
        let h = histogram(&xs, 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 2]); // 2.0 out of range, 0.55 & 0.9 in bin 1
    }

    #[test]
    fn gaussian_peak_at_mu() {
        let p0 = gaussian_pdf(5.0, 5.0, 2.0);
        assert!(p0 > gaussian_pdf(6.0, 5.0, 2.0));
        assert!((p0 - 1.0 / (2.0 * (2.0 * std::f64::consts::PI).sqrt())).abs() < 1e-12);
    }
}
