//! Deterministic, seedable random numbers: PCG32 core seeded through
//! SplitMix64, plus the distributions the simulator and generators need
//! (uniform, normal, lognormal, exponential, Poisson, choice/shuffle).
//!
//! Every experiment in `EXPERIMENTS.md` quotes its seed; identical seeds
//! reproduce identical runs bit-for-bit.

/// PCG32 (XSH-RR variant) — small, fast, statistically solid.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
    /// cached second normal from Box–Muller
    spare_normal: Option<f64>,
}

const PCG_MULT: u64 = 6364136223846793005;

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let init_state = splitmix64(&mut sm);
        let init_inc = splitmix64(&mut sm) | 1; // stream must be odd
        let mut rng = Rng {
            state: 0,
            inc: init_inc,
            spare_normal: None,
        };
        rng.state = rng.state.wrapping_add(init_state);
        rng.next_u32();
        rng
    }

    /// Derive the `idx`-th child stream of `seed` — the seeding rule
    /// behind every parallel experiment fan-out (ROADMAP §Experiment
    /// parallelism).  A fork is a *pure function* of `(seed, idx)`: it
    /// reads no generator state, so forked streams are deterministic,
    /// identical no matter which order (or thread) forks them, and
    /// pairwise distinct across indices for a fixed parent seed (both
    /// the index mix and the SplitMix64 finalizer are bijections, so
    /// distinct indices produce distinct child seeds).
    pub fn fork(seed: u64, idx: u64) -> Rng {
        let mut s = seed;
        let parent = splitmix64(&mut s);
        let mut child = parent
            ^ idx
                .wrapping_mul(0xA24BAED4963EE407)
                .wrapping_add(0x9E3779B97F4A7C15);
        Rng::new(splitmix64(&mut child))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo + 1) as u64;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform usize in [0, n) — panics if n == 0.
    pub fn index(&mut self, n: usize) -> usize {
        assert!(n > 0, "index() on empty range");
        (self.next_u64() % n as u64) as usize
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, prob: f64) -> bool {
        self.f64() < prob
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // avoid log(0)
        let u1 = (1.0 - self.f64()).max(1e-300);
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal with the *underlying* normal's mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Exponential with given rate (mean 1/rate).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -(1.0 - self.f64()).max(1e-300).ln() / rate
    }

    /// Poisson sample (Knuth for small lambda, normal approx for large).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda > 30.0 {
            let v = self.normal_ms(lambda, lambda.sqrt()).round();
            return v.max(0.0) as u64;
        }
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Pick a reference from a non-empty slice.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.index(items.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.index(i + 1);
            items.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.normal();
            s1 += z;
            s2 += z * z;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn int_in_covers_range() {
        let mut r = Rng::new(3);
        let mut seen = [false; 5];
        for _ in 0..200 {
            seen[(r.int_in(10, 14) - 10) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn poisson_mean_tracks_lambda() {
        let mut r = Rng::new(5);
        for &lambda in &[0.5, 3.0, 50.0] {
            let n = 20_000;
            let sum: u64 = (0..n).map(|_| r.poisson(lambda)).sum();
            let mean = sum as f64 / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.07,
                "lambda={lambda} mean={mean}"
            );
        }
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(9);
        let n = 30_000;
        let sum: f64 = (0..n).map(|_| r.exponential(2.0)).sum();
        assert!((sum / n as f64 - 0.5).abs() < 0.02);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // vanishing-prob failure
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut a = Rng::fork(21, 0);
        let mut b = Rng::fork(21, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn fork_is_pure_in_seed_and_index() {
        for idx in [0u64, 1, 7, 600, u64::MAX] {
            let mut a = Rng::fork(0x46a, idx);
            let mut b = Rng::fork(0x46a, idx);
            for _ in 0..16 {
                assert_eq!(a.next_u64(), b.next_u64());
            }
        }
    }

    #[test]
    fn fork_decorrelates_from_parent_stream() {
        let mut parent = Rng::new(9);
        let mut child = Rng::fork(9, 0);
        let same = (0..64)
            .filter(|_| parent.next_u64() == child.next_u64())
            .count();
        assert!(same < 2);
    }
}
