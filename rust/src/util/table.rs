//! ASCII table rendering for the experiment benches: the `exp_*`
//! binaries print the same rows/series the paper's tables and figures
//! report, and this keeps them legible.

/// A simple left-aligned-text / right-aligned-number table.
#[derive(Debug, Default)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let sep: String = widths
            .iter()
            .map(|w| format!("+{}", "-".repeat(w + 2)))
            .collect::<String>()
            + "+\n";
        out.push_str(&sep);
        out.push('|');
        for i in 0..ncol {
            out.push_str(&format!(" {:<w$} |", self.header[i], w = widths[i]));
        }
        out.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push('|');
            for (i, c) in row.iter().enumerate() {
                // right-align numeric-looking cells
                let numeric = c
                    .trim_start_matches('-')
                    .chars()
                    .all(|ch| ch.is_ascii_digit() || ch == '.' || ch == 'x' || ch == '%');
                if numeric && !c.is_empty() {
                    out.push_str(&format!(" {:>w$} |", c, w = widths[i]));
                } else {
                    out.push_str(&format!(" {:<w$} |", c, w = widths[i]));
                }
            }
            out.push('\n');
        }
        out.push_str(&sep);
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a throughput in Mbps with sensible precision.
pub fn fmt_mbps(v: f64) -> String {
    if v >= 1000.0 {
        format!("{:.2}", v / 1000.0) + " Gbps"
    } else {
        format!("{v:.1} Mbps")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["model", "thr"]);
        t.row_strs(&["ASM", "950.0"]);
        t.row_strs(&["HARP", "550.123"]);
        let s = t.render();
        assert!(s.contains("| model"));
        assert!(s.contains("ASM"));
        let lines: Vec<&str> = s.lines().collect();
        let w = lines[0].len();
        assert!(lines.iter().all(|l| l.len() == w), "ragged table:\n{s}");
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn mbps_formatting() {
        assert_eq!(fmt_mbps(123.45), "123.5 Mbps");
        assert_eq!(fmt_mbps(2500.0), "2.50 Gbps");
    }
}
