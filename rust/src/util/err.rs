//! Minimal error handling replacing `anyhow`, which is unresolvable in
//! this offline environment (DESIGN.md §4): a single string-backed
//! [`Error`] with context chaining (`context` / `with_context` on both
//! `Result` and `Option`), a [`crate::bail!`] macro and a `Result`
//! alias.
//!
//! Context is flattened eagerly into one `a: b: c` chain, so both `{e}`
//! and `{e:#}` print the full story — callers that formatted
//! `anyhow::Error` with the alternate flag keep working unchanged.

use std::fmt;

/// Crate-wide result alias (defaults to [`Error`]).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A flattened error message chain.
///
/// Deliberately does *not* implement `std::error::Error`; that keeps
/// the blanket `From<E: std::error::Error>` conversion below coherent
/// (the same trick `anyhow` uses), so `?` works on `io::Error`,
/// [`crate::util::json::ParseError`] and friends.
pub struct Error(String);

impl Error {
    /// Build an error from any displayable message.
    pub fn msg(m: impl fmt::Display) -> Error {
        Error(m.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut msg = e.to_string();
        let mut src = e.source();
        while let Some(s) = src {
            msg.push_str(": ");
            msg.push_str(&s.to_string());
            src = s.source();
        }
        Error(msg)
    }
}

/// `anyhow::Context`-style adapters for `Result` and `Option`.
pub trait Context<T> {
    /// Attach a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Attach a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

/// Early-return with a formatted [`Error`] (mirrors `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::err::Error::msg(format!($($arg)*)))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/path/twophase")?;
        Ok(())
    }

    fn bails(n: u32) -> Result<u32> {
        if n > 3 {
            bail!("n too large: {n}");
        }
        Ok(n)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = fails_io().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn bail_formats() {
        assert_eq!(bails(2).unwrap(), 2);
        assert_eq!(bails(9).unwrap_err().to_string(), "n too large: 9");
    }

    #[test]
    fn context_chains_on_result_and_option() {
        let r: std::result::Result<(), &str> = Err("root cause");
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: root cause");
        // alternate formatting prints the same full chain
        assert_eq!(format!("{e:#}"), "outer: root cause");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
    }

    #[test]
    fn nested_context_accumulates() {
        let r: std::result::Result<(), &str> = Err("c");
        let e = r.context("b").context("a").unwrap_err();
        assert_eq!(e.to_string(), "a: b: c");
    }
}
