//! Wallclock bench harness (criterion is unavailable offline —
//! DESIGN.md §4).  The `rust/benches/*.rs` binaries (`harness = false`)
//! use [`bench`] for timed sections and print criterion-style summary
//! lines: median with p10/p90 spread over N timed iterations after a
//! warmup.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median: Duration,
    pub p10: Duration,
    pub p90: Duration,
    pub mean: Duration,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<40} iters={:<4} median={:>12?} p10={:>12?} p90={:>12?}",
            self.name, self.iters, self.median, self.p10, self.p90
        )
    }

    /// Median in nanoseconds (for throughput math in perf logs).
    pub fn median_ns(&self) -> f64 {
        self.median.as_nanos() as f64
    }
}

fn percentile_dur(sorted: &[Duration], q: f64) -> Duration {
    if sorted.is_empty() {
        return Duration::ZERO;
    }
    let rank = (q * (sorted.len() - 1) as f64).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Time `f` for `iters` iterations after `warmup` untimed runs.
/// Returns per-iteration statistics and prints the summary line.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
    }
    samples.sort();
    let mean_ns: u128 = samples.iter().map(|d| d.as_nanos()).sum::<u128>() / iters as u128;
    let res = BenchResult {
        name: name.to_string(),
        iters,
        median: percentile_dur(&samples, 0.5),
        p10: percentile_dur(&samples, 0.1),
        p90: percentile_dur(&samples, 0.9),
        mean: Duration::from_nanos(mean_ns as u64),
    };
    println!("{}", res.line());
    res
}

/// Time a single run of `f` and return (result, elapsed).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop-ish", 2, 20, || {
            std::hint::black_box((0..100).sum::<u64>());
        });
        assert_eq!(r.iters, 20);
        assert!(r.p10 <= r.median && r.median <= r.p90);
    }

    #[test]
    fn time_once_returns_value() {
        let (v, d) = time_once(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(d < Duration::from_secs(1));
    }
}
