//! In-tree infrastructure replacing crates that are unresolvable in this
//! offline environment (see `DESIGN.md §4`): seeded RNG, JSON, CLI
//! parsing, statistics, small-matrix linear algebra, a property-testing
//! mini-framework, a wallclock bench harness, a deterministic scoped
//! thread pool ([`par`]), and a deterministic sim-time tracing/metrics
//! layer ([`trace`]).

pub mod cli;
pub mod err;
pub mod json;
pub mod linalg;
pub mod par;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timer;
pub mod trace;
