//! Property-testing mini-framework (proptest is unavailable offline —
//! DESIGN.md §4).
//!
//! A property is a closure over a [`Gen`] (a seeded value source); the
//! runner executes it for many cases and, on failure, re-runs with a
//! reduced `size` budget to report the smallest failing scale it can
//! find (coarse-grained shrinking).
//!
//! ```no_run
//! # // no_run: rustdoc test binaries miss the xla rpath in this image
//! use twophase::util::prop::{run, Gen};
//! run("reverse twice is identity", 100, |g| {
//!     let v = g.vec_f64(0..=32, -1e3..1e3);
//!     let mut w = v.clone();
//!     w.reverse();
//!     w.reverse();
//!     assert_eq!(v, w);
//! });
//! ```

use crate::util::rng::Rng;
use std::ops::{Range, RangeInclusive};

/// Seeded generator handed to properties.
pub struct Gen {
    rng: Rng,
    /// scale knob in (0, 1]; shrink passes reduce it
    pub size: f64,
}

impl Gen {
    fn new(seed: u64, size: f64) -> Gen {
        Gen {
            rng: Rng::new(seed),
            size,
        }
    }

    /// Access the raw RNG.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn f64_in(&mut self, range: Range<f64>) -> f64 {
        self.rng.uniform(range.start, range.end)
    }

    /// Integer in an inclusive range, biased small by the size budget.
    pub fn usize_in(&mut self, range: RangeInclusive<usize>) -> usize {
        let (lo, hi) = (*range.start(), *range.end());
        let span = ((hi - lo) as f64 * self.size).round() as usize;
        lo + self.rng.index(span.max(0) + 1)
    }

    pub fn u32_in(&mut self, range: RangeInclusive<u32>) -> u32 {
        self.usize_in(*range.start() as usize..=*range.end() as usize) as u32
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn vec_f64(&mut self, len: RangeInclusive<usize>, range: Range<f64>) -> Vec<f64> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f64_in(range.clone())).collect()
    }

    /// Strictly increasing knot vector of length n with steps in [0.25, 2].
    pub fn knots(&mut self, n: usize) -> Vec<f64> {
        let mut xs = Vec::with_capacity(n);
        let mut x = self.f64_in(0.5..2.0);
        for _ in 0..n {
            xs.push(x);
            x += self.f64_in(0.25..2.0);
        }
        xs
    }
}

/// Run `cases` random cases of the property.  Panics (failing the test)
/// with seed + case details on the first failure, after attempting a
/// smaller-size reproduction.
pub fn run<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: u32, prop: F) {
    run_seeded(name, 0xC0FFEE, cases, prop)
}

/// As [`run`] but with an explicit base seed (quoted in failure output).
pub fn run_seeded<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    name: &str,
    base_seed: u64,
    cases: u32,
    prop: F,
) {
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let outcome = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed, 1.0);
            prop(&mut g);
        });
        if let Err(panic) = outcome {
            // coarse shrink: try progressively smaller size budgets with
            // the same seed and report the smallest that still fails.
            let mut smallest: Option<f64> = None;
            for &size in &[0.1, 0.25, 0.5] {
                let again = std::panic::catch_unwind(|| {
                    let mut g = Gen::new(seed, size);
                    prop(&mut g);
                });
                if again.is_err() {
                    smallest = Some(size);
                    break;
                }
            }
            let msg = panic
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            // pallas-lint: allow(panic-in-lib, the property harness reports failures by panicking, mirroring assert! — swallowing the failure would make every property test pass vacuously)
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}, \
                 min failing size {:?}): {msg}",
                smallest.unwrap_or(1.0)
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        run("abs is nonnegative", 200, |g| {
            let x = g.f64_in(-100.0..100.0);
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    fn generators_respect_bounds() {
        run("bounds", 200, |g| {
            let n = g.usize_in(2..=9);
            assert!((2..=9).contains(&n));
            let v = g.vec_f64(1..=5, 0.0..1.0);
            assert!(!v.is_empty() && v.len() <= 5);
            assert!(v.iter().all(|x| (0.0..1.0).contains(x)));
        });
    }

    #[test]
    fn knots_strictly_increasing() {
        run("knots", 100, |g| {
            let ks = g.knots(8);
            assert!(ks.windows(2).all(|w| w[1] > w[0]));
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            run("always fails", 5, |_| panic!("boom"));
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("seed"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn deterministic_given_seed() {
        let mut first = Vec::new();
        run_seeded("collect", 0xABCD, 3, |g| {
            // not a real property; we just confirm determinism by
            // recreating the generator stream manually below.
            let _ = g.f64_in(0.0..1.0);
        });
        for case in 0..3u64 {
            let seed = 0xABCDu64
                .wrapping_mul(0x9E3779B97F4A7C15)
                .wrapping_add(case);
            let mut g = Gen::new(seed, 1.0);
            first.push(g.f64_in(0.0..1.0));
        }
        let second: Vec<f64> = (0..3u64)
            .map(|case| {
                let seed = 0xABCDu64
                    .wrapping_mul(0x9E3779B97F4A7C15)
                    .wrapping_add(case);
                let mut g = Gen::new(seed, 1.0);
                g.f64_in(0.0..1.0)
            })
            .collect();
        assert_eq!(first, second);
    }
}
