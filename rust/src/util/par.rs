//! Zero-dependency deterministic thread pool (scoped, work-stealing-lite).
//!
//! The offline-discovery pipeline fans out embarrassingly-parallel units
//! (k-sweep restarts, HAC distance rows, per-cluster surface fits,
//! experiment grid cells) over OS threads while keeping the output
//! **bit-identical** to a serial run:
//!
//! * work units are indexed and their results are reassembled in index
//!   order, so any floating-point reduction downstream sees the exact
//!   same operand order regardless of thread count;
//! * chunk boundaries are fixed by the caller (never derived from the
//!   thread count), so per-chunk partial sums are identical whether one
//!   thread or eight drained the queue;
//! * the serial path (`threads == 1`) runs the very same closure over
//!   the very same units — it is the degenerate pool, not special code.
//!
//! Scheduling is a shared atomic cursor: each worker claims the next
//! unclaimed index, which is the "stealing-lite" half — no per-worker
//! deques, but also no static striping, so a slow unit never stalls the
//! rest of the queue.
//!
//! `PALLAS_THREADS` overrides the worker count (read at call time, so
//! tests and benches can flip it per-section); nested `par_map` calls
//! from inside a pool worker degrade to serial to avoid thread
//! explosion when parallel layers compose (pipeline → surface fit →
//! spline rows).

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;

thread_local! {
    /// Set inside pool workers so nested `par_map` calls run serial.
    static IN_POOL_WORKER: Cell<bool> = const { Cell::new(false) };
}

/// Process-wide fan-out counters for [`crate::util::trace`]: how many
/// `par_map_with` entries ran and how many work units they covered.
/// Both are counted unconditionally (serial fallback included), so the
/// totals are **thread-invariant** — they depend only on the work
/// submitted, never on `PALLAS_THREADS` or nesting depth.  Tracers
/// snapshot these at construction and report deltas.
static FANOUT_CALLS: AtomicU64 = AtomicU64::new(0);
static FANOUT_UNITS: AtomicU64 = AtomicU64::new(0);

/// Snapshot of the fan-out counters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FanoutStats {
    /// `par_map_with` invocations (including serial-degraded ones).
    pub calls: u64,
    /// total work units submitted across those invocations.
    pub units: u64,
}

/// Current process-wide fan-out totals (monotone).
pub fn fanout_stats() -> FanoutStats {
    FanoutStats {
        calls: FANOUT_CALLS.load(Ordering::Relaxed),
        units: FANOUT_UNITS.load(Ordering::Relaxed),
    }
}

/// True when the current thread is a pool worker (nested call site).
pub fn in_worker() -> bool {
    IN_POOL_WORKER.with(|f| f.get())
}

/// Worker count: `PALLAS_THREADS` if set and >= 1, else the machine's
/// available parallelism, else 1.
pub fn max_threads() -> usize {
    if let Ok(v) = std::env::var("PALLAS_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Map `f` over `items` with the default worker count, preserving
/// order.  `f` receives `(index, &item)`.  Bit-identical to serial for
/// any thread count.
pub fn par_map<T, U, F>(items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> U + Sync,
{
    par_map_with(max_threads(), items, f)
}

/// Map with an explicit worker count.  Runs serial when `threads <= 1`,
/// when there are fewer than two items, or when called from inside a
/// pool worker (nested parallelism guard).
pub fn par_map_with<T, U, F>(threads: usize, items: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> U + Sync,
{
    let n = items.len();
    FANOUT_CALLS.fetch_add(1, Ordering::Relaxed);
    FANOUT_UNITS.fetch_add(n as u64, Ordering::Relaxed);
    if threads <= 1 || n < 2 || in_worker() {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let workers = threads.min(n);
    let cursor = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, U)>();
    let mut slots: Vec<Option<U>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    std::thread::scope(|s| {
        for _ in 0..workers {
            let tx = tx.clone();
            let cursor = &cursor;
            let f = &f;
            s.spawn(move || {
                IN_POOL_WORKER.with(|flag| flag.set(true));
                loop {
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    // pallas-lint: allow(panic-in-lib, a dropped receiver is impossible while the scope lives; the unwrap keeps worker panics loud instead of silently losing units)
                    tx.send((i, f(i, &items[i]))).unwrap();
                }
            });
        }
        drop(tx);
        for (i, v) in rx {
            slots[i] = Some(v);
        }
    });
    slots
        .into_iter()
        // pallas-lint: allow(panic-in-lib, a missing slot means a worker died mid-unit; silent loss would corrupt the ordered reduction, so abort loudly)
        .map(|v| v.expect("pool worker dropped a unit"))
        .collect()
}

/// Map `f` over the index range `0..n` with the default worker count,
/// preserving index order — the unit-indexed sibling of [`par_map`]
/// for fan-outs whose work is defined by an index alone (experiment
/// grid cells, per-day history shards, per-cell RNG forks).  Same
/// determinism contract: bit-identical to serial for any thread count.
pub fn par_indices<U, F>(n: usize, f: F) -> Vec<U>
where
    U: Send,
    F: Fn(usize) -> U + Sync,
{
    let idx: Vec<usize> = (0..n).collect();
    par_map(&idx, |i, _| f(i))
}

/// Chunked map: splits `items` into fixed `chunk`-sized windows, maps
/// each window to a `Vec<U>`, and flattens in window order.  Because
/// the chunk boundaries depend only on `chunk` (not the thread count),
/// per-chunk floating-point partials are reproducible bit-for-bit.
/// `f` receives `(chunk_start_index, window)`.
pub fn par_chunk_map<T, U, F>(items: &[T], chunk: usize, f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &[T]) -> Vec<U> + Sync,
{
    let chunk = chunk.max(1);
    let windows: Vec<(usize, &[T])> = items
        .chunks(chunk)
        .enumerate()
        .map(|(ci, w)| (ci * chunk, w))
        .collect();
    let parts = par_map(&windows, |_, &(start, w)| f(start, w));
    let mut out = Vec::with_capacity(items.len());
    for p in parts {
        out.extend(p);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes tests that mutate `PALLAS_THREADS` (process-global).
    static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    #[test]
    fn par_map_matches_serial_any_thread_count() {
        let items: Vec<f64> = (0..257).map(|i| (i as f64).sin()).collect();
        let serial = par_map_with(1, &items, |i, x| x * (i as f64 + 0.5));
        for threads in [2, 3, 8] {
            let par = par_map_with(threads, &items, |i, x| x * (i as f64 + 0.5));
            for (a, b) in serial.iter().zip(&par) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = par_map_with(4, &items, |i, &x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn par_chunk_map_fixed_boundaries() {
        let items: Vec<f64> = (0..1000).map(|i| 1.0 / (i as f64 + 1.0)).collect();
        // Per-chunk serial partial sums, flattened in chunk order.
        let sums = |_: usize, w: &[f64]| vec![w.iter().sum::<f64>()];
        let _g = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let serial = par_chunk_map(&items, 64, sums);
        std::env::set_var("PALLAS_THREADS", "7");
        let par = par_chunk_map(&items, 64, sums);
        std::env::remove_var("PALLAS_THREADS");
        assert_eq!(serial.len(), par.len());
        for (a, b) in serial.iter().zip(&par) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn nested_par_map_degrades_to_serial() {
        let outer: Vec<usize> = (0..8).collect();
        let out = par_map_with(4, &outer, |_, &x| {
            // Inside a worker the nested call must run serial.
            let inner: Vec<usize> = (0..4).collect();
            let nested = par_map_with(4, &inner, |_, &y| {
                assert!(in_worker());
                y + x
            });
            nested.iter().sum::<usize>()
        });
        assert_eq!(out[0], 6); // 0+1+2+3, x = 0
        assert_eq!(out.len(), 8);
    }

    #[test]
    fn par_indices_matches_serial_and_preserves_order() {
        let serial: Vec<usize> = (0..97).map(|i| i * 3 + 1).collect();
        assert_eq!(par_indices(97, |i| i * 3 + 1), serial);
        assert!(par_indices(0, |i| i).is_empty());
    }

    #[test]
    fn empty_and_single_item_inputs() {
        let empty: Vec<u32> = Vec::new();
        assert!(par_map_with(8, &empty, |_, &x| x).is_empty());
        let one = [42u32];
        assert_eq!(par_map_with(8, &one, |_, &x| x + 1), vec![43]);
    }

    #[test]
    fn fanout_stats_count_serial_and_parallel_calls() {
        let before = fanout_stats();
        let items: Vec<u32> = (0..5).collect();
        let _ = par_map_with(1, &items, |_, &x| x);
        let _ = par_map_with(4, &items, |_, &x| x);
        let after = fanout_stats();
        // >= because sibling tests in this binary also bump the totals
        assert!(after.calls >= before.calls + 2);
        assert!(after.units >= before.units + 10);
    }

    #[test]
    fn max_threads_respects_env_override() {
        let _g = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        std::env::set_var("PALLAS_THREADS", "3");
        assert_eq!(max_threads(), 3);
        std::env::set_var("PALLAS_THREADS", "0");
        assert_eq!(max_threads(), 1); // clamped to >= 1
        std::env::remove_var("PALLAS_THREADS");
        assert!(max_threads() >= 1);
    }
}
