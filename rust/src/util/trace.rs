//! Deterministic tracing + metrics: sim-time-stamped span/event
//! records and a counter/gauge/histogram registry, exported as JSONL
//! through [`crate::util::json`].
//!
//! Determinism contract (the same discipline as [`crate::util::par`]'s
//! ordered reduction):
//!
//! * **R3** — every timestamp is simulation time (the engine's
//!   `now_s`); no wall clocks ever enter a record, so a trace is a pure
//!   function of seeds and configuration;
//! * **R1** — all keyed state is `BTreeMap`, so iteration (and hence
//!   serialization) order is total and stable;
//! * **thread invariance** — records are buffered per *scope* (one
//!   scope per transfer execution, keyed by `(request id, run)`), and
//!   the exporter walks scopes in key order, assigning global sequence
//!   numbers and folding metric deltas in that order.  Scheduling can
//!   reorder when scopes *flush*, never how they *export*: the JSONL
//!   bytes are identical for any `PALLAS_THREADS` setting
//!   (`tests/prop_trace.rs` proves it at 1/2/8 threads).
//!
//! The only process-global inputs are [`crate::util::par`]'s fan-out
//! counters, which are sums of thread-invariant quantities (call and
//! unit counts never depend on the worker count); the tracer snapshots
//! them at construction and exports the delta.
//!
//! # Export format
//!
//! One JSON object per line, four `kind`s:
//!
//! ```text
//! {"kind":"meta","format":"twophase-trace","version":1,"scopes":N,"records":M}
//! {"kind":"span","name":"transfer","scope":3,"run":0,"seq":7,"t_s":0,"dur_s":412.8,"fields":{...}}
//! {"kind":"event","name":"asm.converged","scope":3,"run":0,"seq":2,"t_s":18.4,"fields":{...}}
//! {"kind":"metric","name":"chunks","type":"counter","value":96}
//! ```
//!
//! `scripts/trace-schema.golden` pins the field names (not values) and
//! `scripts/ci.sh` checks a smoke trace against it.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Mutex, MutexGuard};

use crate::util::err::Result;
use crate::util::json::Value;
use crate::util::par;

// ---------------------------------------------------------------------
// records
// ---------------------------------------------------------------------

/// Span (has a duration) or point event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecordKind {
    Span,
    Event,
}

impl RecordKind {
    pub fn label(&self) -> &'static str {
        match self {
            RecordKind::Span => "span",
            RecordKind::Event => "event",
        }
    }
}

/// One trace record.  `t_s` is simulation time; spans carry the extra
/// `dur_s`.  Fields keep their emission order here and are sorted by
/// the JSON object writer at export, so field *insertion* order never
/// leaks into the bytes.
#[derive(Debug, Clone)]
pub struct Record {
    pub kind: RecordKind,
    pub name: &'static str,
    pub t_s: f64,
    /// span duration; None for events
    pub dur_s: Option<f64>,
    pub fields: Vec<(&'static str, Value)>,
}

/// An event minted by a layer that knows *what* happened but not
/// *when* in sim time (e.g. the online controller, which has no clock):
/// the owner of the [`TraceScope`] stamps it on drain.
#[derive(Debug, Clone)]
pub struct PendingEvent {
    pub name: &'static str,
    pub fields: Vec<(&'static str, Value)>,
}

impl PendingEvent {
    pub fn new(name: &'static str, fields: Vec<(&'static str, Value)>) -> PendingEvent {
        PendingEvent { name, fields }
    }
}

// ---------------------------------------------------------------------
// metrics
// ---------------------------------------------------------------------

/// Summary histogram: count / sum / min / max.  The sum is folded in
/// scope-key order at export, so its f64 bit pattern is reproducible.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Histogram {
    pub count: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Histogram {
    fn new() -> Histogram {
        Histogram {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn observe(&mut self, v: f64) {
        self.count += 1;
        self.sum += v;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

/// One named metric.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Metric {
    Counter(u64),
    Gauge(f64),
    Histogram(Histogram),
}

/// Deterministic metric store: `BTreeMap` keyed by name, exported in
/// name order.  A name's type is fixed by its first operation;
/// mismatched later operations are ignored rather than panicking
/// (library code must not panic — R5).
#[derive(Debug, Clone, Default)]
pub struct MetricsRegistry {
    metrics: BTreeMap<&'static str, Metric>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    pub fn counter_add(&mut self, name: &'static str, n: u64) {
        if let Metric::Counter(c) = self.metrics.entry(name).or_insert(Metric::Counter(0)) {
            *c += n;
        }
    }

    pub fn gauge_set(&mut self, name: &'static str, v: f64) {
        if let Metric::Gauge(g) = self.metrics.entry(name).or_insert(Metric::Gauge(v)) {
            *g = v;
        }
    }

    pub fn observe(&mut self, name: &'static str, v: f64) {
        if let Metric::Histogram(h) = self
            .metrics
            .entry(name)
            .or_insert(Metric::Histogram(Histogram::new()))
        {
            h.observe(v);
        }
    }

    pub fn get(&self, name: &str) -> Option<&Metric> {
        self.metrics.get(name)
    }

    /// Counter value, 0 when absent or a different type.
    pub fn counter(&self, name: &str) -> u64 {
        match self.metrics.get(name) {
            Some(Metric::Counter(c)) => *c,
            _ => 0,
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = (&'static str, &Metric)> {
        self.metrics.iter().map(|(k, v)| (*k, v))
    }

    pub fn len(&self) -> usize {
        self.metrics.len()
    }

    pub fn is_empty(&self) -> bool {
        self.metrics.is_empty()
    }

    fn apply(&mut self, op: &MetricOp) {
        match *op {
            MetricOp::Count(name, n) => self.counter_add(name, n),
            MetricOp::Gauge(name, v) => self.gauge_set(name, v),
            MetricOp::Observe(name, v) => self.observe(name, v),
        }
    }
}

/// A buffered metric mutation (replayed in scope-key order at export).
#[derive(Debug, Clone, Copy)]
enum MetricOp {
    Count(&'static str, u64),
    Gauge(&'static str, f64),
    Observe(&'static str, f64),
}

// ---------------------------------------------------------------------
// tracer + scopes
// ---------------------------------------------------------------------

#[derive(Debug, Default)]
struct ScopeData {
    records: Vec<Record>,
    ops: Vec<MetricOp>,
}

#[derive(Debug, Default)]
struct TracerInner {
    /// finished scopes keyed by (scope id, run) — run disambiguates
    /// repeated executions of the same request id
    scopes: BTreeMap<(u64, u64), ScopeData>,
    /// next run number per scope id
    runs: BTreeMap<u64, u64>,
}

/// The collection point.  Shareable across the orchestrator's worker
/// threads (`Arc<Tracer>`); all mutation happens at scope open/flush,
/// never per record, so tracing adds no lock traffic to the chunk loop.
#[derive(Debug, Default)]
pub struct Tracer {
    inner: Mutex<TracerInner>,
    /// `util::par` fan-out counters at construction; export reports
    /// the delta so a tracer only sees its own window.
    par_calls0: u64,
    par_units0: u64,
}

impl Tracer {
    pub fn new() -> Tracer {
        let fan = par::fanout_stats();
        Tracer {
            inner: Mutex::new(TracerInner::default()),
            par_calls0: fan.calls,
            par_units0: fan.units,
        }
    }

    /// Lock the collector, recovering from a poisoned mutex (scope
    /// buffers are plain data; a panicking worker leaves them valid).
    fn lock(&self) -> MutexGuard<'_, TracerInner> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Open a buffering scope for `scope_id` (one per transfer
    /// execution).  Repeated opens for the same id get increasing run
    /// numbers, so clean/faulted replays of one request stay distinct.
    /// (Associated fn, not a method: the scope keeps an owned `Arc` so
    /// it can flush on drop.)
    pub fn scope(tracer: &Arc<Tracer>, scope_id: u64) -> TraceScope {
        let run = {
            let mut inner = tracer.lock();
            let r = inner.runs.entry(scope_id).or_insert(0);
            let run = *r;
            *r += 1;
            run
        };
        TraceScope {
            sink: Some((Arc::clone(tracer), scope_id, run)),
            data: ScopeData::default(),
        }
    }

    /// Scope against an optional tracer: `None` yields the no-op scope.
    pub fn scope_opt(tracer: Option<&Arc<Tracer>>, scope_id: u64) -> TraceScope {
        match tracer {
            Some(t) => Tracer::scope(t, scope_id),
            None => TraceScope::disabled(),
        }
    }

    fn absorb(&self, key: (u64, u64), data: ScopeData) {
        let mut inner = self.lock();
        let slot = inner.scopes.entry(key).or_default();
        slot.records.extend(data.records);
        slot.ops.extend(data.ops);
    }

    /// Fold every flushed scope's metric ops (scope-key order) plus the
    /// `util::par` fan-out delta into one registry.
    pub fn metrics(&self) -> MetricsRegistry {
        let inner = self.lock();
        let mut reg = MetricsRegistry::new();
        for data in inner.scopes.values() {
            for op in &data.ops {
                reg.apply(op);
            }
        }
        drop(inner);
        let fan = par::fanout_stats();
        reg.counter_add("par.fanout_calls", fan.calls - self.par_calls0);
        reg.counter_add("par.fanout_units", fan.units - self.par_units0);
        reg
    }

    /// The full deterministic JSONL export (meta, records in scope-key
    /// order with global sequence numbers, metrics in name order).
    pub fn export_string(&self) -> String {
        let reg = self.metrics();
        let inner = self.lock();
        let n_records: usize = inner.scopes.values().map(|d| d.records.len()).sum();
        let mut out = String::new();
        let meta = Value::obj(vec![
            ("kind", Value::str("meta")),
            ("format", Value::str("twophase-trace")),
            ("version", Value::Num(1.0)),
            ("scopes", Value::Num(inner.scopes.len() as f64)),
            ("records", Value::Num(n_records as f64)),
        ]);
        out.push_str(&meta.to_string());
        out.push('\n');
        let mut seq = 0u64;
        for (&(scope_id, run), data) in &inner.scopes {
            for rec in &data.records {
                let mut pairs = vec![
                    ("kind", Value::str(rec.kind.label())),
                    ("name", Value::str(rec.name)),
                    ("scope", Value::Num(scope_id as f64)),
                    ("run", Value::Num(run as f64)),
                    ("seq", Value::Num(seq as f64)),
                    ("t_s", Value::Num(rec.t_s)),
                ];
                if let Some(d) = rec.dur_s {
                    pairs.push(("dur_s", Value::Num(d)));
                }
                let fields: BTreeMap<String, Value> = rec
                    .fields
                    .iter()
                    .map(|(k, v)| (k.to_string(), v.clone()))
                    .collect();
                pairs.push(("fields", Value::Obj(fields)));
                out.push_str(&Value::obj(pairs).to_string());
                out.push('\n');
                seq += 1;
            }
        }
        drop(inner);
        for (name, metric) in reg.iter() {
            let mut pairs = vec![("kind", Value::str("metric")), ("name", Value::str(name))];
            match metric {
                Metric::Counter(c) => {
                    pairs.push(("type", Value::str("counter")));
                    pairs.push(("value", Value::Num(*c as f64)));
                }
                Metric::Gauge(g) => {
                    pairs.push(("type", Value::str("gauge")));
                    pairs.push(("value", Value::Num(*g)));
                }
                Metric::Histogram(h) => {
                    pairs.push(("type", Value::str("histogram")));
                    pairs.push(("count", Value::Num(h.count as f64)));
                    pairs.push(("sum", Value::Num(h.sum)));
                    pairs.push(("min", Value::Num(h.min)));
                    pairs.push(("max", Value::Num(h.max)));
                }
            }
            out.push_str(&Value::obj(pairs).to_string());
            out.push('\n');
        }
        out
    }

    /// Write the JSONL export to a file.
    pub fn write_jsonl(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.export_string())?;
        Ok(())
    }

    /// One-line human summary (bench/CLI output).
    pub fn summary(&self) -> String {
        let reg = self.metrics();
        let inner = self.lock();
        let mut spans = 0usize;
        let mut events = 0usize;
        for d in inner.scopes.values() {
            for r in &d.records {
                match r.kind {
                    RecordKind::Span => spans += 1,
                    RecordKind::Event => events += 1,
                }
            }
        }
        format!(
            "trace: {} scopes, {} spans, {} events, {} metrics",
            inner.scopes.len(),
            spans,
            events,
            reg.len()
        )
    }
}

/// Per-execution record buffer.  All methods are no-ops on the
/// disabled scope, so instrumented code never branches on whether a
/// tracer is attached.  Flushes into the tracer on drop.
#[derive(Debug)]
pub struct TraceScope {
    sink: Option<(Arc<Tracer>, u64, u64)>,
    data: ScopeData,
}

impl TraceScope {
    /// The no-op scope (no tracer attached).
    pub fn disabled() -> TraceScope {
        TraceScope {
            sink: None,
            data: ScopeData::default(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.sink.is_some()
    }

    /// Record a point event at sim time `t_s`.
    pub fn event(&mut self, name: &'static str, t_s: f64, fields: Vec<(&'static str, Value)>) {
        if self.sink.is_none() {
            return;
        }
        self.data.records.push(Record {
            kind: RecordKind::Event,
            name,
            t_s,
            dur_s: None,
            fields,
        });
    }

    /// Record a completed span covering `[t_start_s, t_end_s]`.
    pub fn span(
        &mut self,
        name: &'static str,
        t_start_s: f64,
        t_end_s: f64,
        fields: Vec<(&'static str, Value)>,
    ) {
        if self.sink.is_none() {
            return;
        }
        self.data.records.push(Record {
            kind: RecordKind::Span,
            name,
            t_s: t_start_s,
            dur_s: Some(t_end_s - t_start_s),
            fields,
        });
    }

    /// Stamp and record events drained from a clock-less layer.
    pub fn stamp(&mut self, t_s: f64, pending: Vec<PendingEvent>) {
        if self.sink.is_none() {
            return;
        }
        for ev in pending {
            self.event(ev.name, t_s, ev.fields);
        }
    }

    pub fn count(&mut self, name: &'static str, n: u64) {
        if self.sink.is_none() {
            return;
        }
        self.data.ops.push(MetricOp::Count(name, n));
    }

    pub fn gauge(&mut self, name: &'static str, v: f64) {
        if self.sink.is_none() {
            return;
        }
        self.data.ops.push(MetricOp::Gauge(name, v));
    }

    pub fn observe(&mut self, name: &'static str, v: f64) {
        if self.sink.is_none() {
            return;
        }
        self.data.ops.push(MetricOp::Observe(name, v));
    }
}

impl Drop for TraceScope {
    fn drop(&mut self) {
        if let Some((tracer, id, run)) = self.sink.take() {
            tracer.absorb((id, run), std::mem::take(&mut self.data));
        }
    }
}

// ---------------------------------------------------------------------
// schema (CI golden check)
// ---------------------------------------------------------------------

/// Extract the trace *schema* from a JSONL export: for every `kind`,
/// the union of top-level field names across its lines, rendered as
/// `kind: a,b,c` lines in kind order.  Values never enter the output,
/// so the golden file in `scripts/` stays stable across data changes.
pub fn schema_of_jsonl(text: &str) -> Result<String> {
    let mut kinds: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Value::parse(line)
            .map_err(|e| crate::util::err::Error::msg(format!("line {}: {e}", i + 1)))?;
        let Some(obj) = v.as_obj() else {
            crate::bail!("line {}: not a JSON object", i + 1);
        };
        let Some(kind) = v.get("kind").as_str() else {
            crate::bail!("line {}: missing \"kind\"", i + 1);
        };
        kinds
            .entry(kind.to_string())
            .or_default()
            .extend(obj.keys().cloned());
    }
    let mut out = String::new();
    for (kind, keys) in &kinds {
        out.push_str(kind);
        out.push_str(": ");
        out.push_str(&keys.iter().cloned().collect::<Vec<_>>().join(","));
        out.push('\n');
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_scope_is_a_noop() {
        let mut s = TraceScope::disabled();
        assert!(!s.enabled());
        s.event("x", 1.0, vec![]);
        s.span("y", 0.0, 2.0, vec![]);
        s.count("c", 3);
        s.observe("h", 1.5);
        drop(s); // nothing to flush, nothing panics
    }

    #[test]
    fn records_and_metrics_round_trip() {
        let t = Arc::new(Tracer::new());
        {
            let mut s = Tracer::scope(&t, 7);
            assert!(s.enabled());
            s.event("asm.sample", 3.5, vec![("bucket", Value::Num(2.0))]);
            s.span("transfer", 0.0, 10.0, vec![("model", Value::str("ASM"))]);
            s.count("chunks", 4);
            s.observe("chunk.throughput_mbps", 800.0);
            s.observe("chunk.throughput_mbps", 400.0);
            s.gauge("sampling_chunks", 6.0);
        }
        let reg = t.metrics();
        assert_eq!(reg.counter("chunks"), 4);
        match reg.get("chunk.throughput_mbps") {
            Some(Metric::Histogram(h)) => {
                assert_eq!(h.count, 2);
                assert_eq!(h.min, 400.0);
                assert_eq!(h.max, 800.0);
                assert_eq!(h.mean(), 600.0);
            }
            other => panic!("expected histogram, got {other:?}"),
        }
        let text = t.export_string();
        for line in text.lines() {
            Value::parse(line).expect("every export line is valid JSON");
        }
        assert!(text.contains("\"kind\":\"meta\""));
        assert!(text.contains("\"kind\":\"span\""));
        assert!(text.contains("\"kind\":\"event\""));
        assert!(text.contains("\"kind\":\"metric\""));
        assert!(t.summary().contains("1 scopes, 1 spans, 1 events"));
    }

    #[test]
    fn repeat_scope_ids_get_distinct_runs() {
        let t = Arc::new(Tracer::new());
        for k in 0..3u64 {
            let mut s = Tracer::scope(&t, 5);
            s.event("e", k as f64, vec![]);
        }
        let text = t.export_string();
        assert!(text.contains("\"run\":0"));
        assert!(text.contains("\"run\":1"));
        assert!(text.contains("\"run\":2"));
    }

    #[test]
    fn export_is_flush_order_independent() {
        // same scopes absorbed in opposite orders => identical bytes
        let build = |ids: &[u64]| {
            let t = Arc::new(Tracer::new());
            for &id in ids {
                let mut s = Tracer::scope(&t, id);
                s.event("e", id as f64, vec![("id", Value::Num(id as f64))]);
                s.count("n", id);
            }
            t.export_string()
        };
        // fan-out counters may advance between builds from other tests
        // in this binary; strip metric lines before comparing records
        let records = |s: String| {
            s.lines()
                .filter(|l| !l.contains("\"kind\":\"metric\""))
                .collect::<Vec<_>>()
                .join("\n")
        };
        assert_eq!(records(build(&[1, 2, 3])), records(build(&[3, 2, 1])));
    }

    #[test]
    fn metric_type_is_fixed_by_first_op() {
        let mut reg = MetricsRegistry::new();
        reg.counter_add("x", 2);
        reg.gauge_set("x", 9.0); // ignored: x is a counter
        reg.observe("x", 1.0); // ignored
        assert_eq!(reg.counter("x"), 2);
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn pending_events_are_stamped() {
        let t = Arc::new(Tracer::new());
        {
            let mut s = Tracer::scope(&t, 1);
            s.stamp(
                42.5,
                vec![PendingEvent::new("asm.retune", vec![("bucket", Value::Num(3.0))])],
            );
        }
        let text = t.export_string();
        assert!(text.contains("\"name\":\"asm.retune\""));
        assert!(text.contains("\"t_s\":42.5"));
    }

    #[test]
    fn schema_extraction() {
        let jsonl = "{\"kind\":\"meta\",\"version\":1}\n\
                     {\"kind\":\"event\",\"name\":\"x\",\"t_s\":1}\n\
                     {\"kind\":\"event\",\"name\":\"y\",\"extra\":true}\n";
        let schema = schema_of_jsonl(jsonl).expect("parses");
        assert_eq!(
            schema,
            "event: extra,kind,name,t_s\nmeta: kind,version\n"
        );
        assert!(schema_of_jsonl("not json\n").is_err());
        assert!(schema_of_jsonl("[1,2]\n").is_err());
    }
}
