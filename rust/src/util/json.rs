//! Minimal JSON: a `Value` tree, a recursive-descent parser and a
//! writer.  Used for the artifact manifest, the log store and the
//! offline knowledge base (serde is unavailable offline — DESIGN.md §4).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.  Object keys are kept sorted (BTreeMap) so that
/// serialization is deterministic — the store's round-trip tests rely
/// on it.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(BTreeMap<String, Value>),
}

#[derive(Debug)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Value {
    // ------------------------------------------------------------------
    // accessors
    // ------------------------------------------------------------------
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|n| {
            if n >= 0.0 && n.fract() == 0.0 {
                Some(n as u64)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `value["key"]`-style access; returns Null for misses.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        self.as_obj().and_then(|o| o.get(key)).unwrap_or(&NULL)
    }

    /// Array element access; Null for misses.
    pub fn at(&self, idx: usize) -> &Value {
        static NULL: Value = Value::Null;
        self.as_arr().and_then(|a| a.get(idx)).unwrap_or(&NULL)
    }

    // ------------------------------------------------------------------
    // builders
    // ------------------------------------------------------------------
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn arr_f64(items: &[f64]) -> Value {
        Value::Arr(items.iter().map(|&x| Value::Num(x)).collect())
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::Str(s.into())
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Value, ParseError> {
        let bytes = text.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

impl fmt::Display for Value {
    /// Compact deterministic serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Null => write!(f, "null"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Value::Str(s) => write_escaped(f, s),
            Value::Arr(a) => {
                write!(f, "[")?;
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Value::Obj(o) => {
                write!(f, "{{")?;
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.eat(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let s = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let Some(c) = s.chars().next() else {
                        return Err(self.err("unterminated string"));
                    };
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad number"))?;
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse("-3.5e2").unwrap(), Value::Num(-350.0));
        assert_eq!(
            Value::parse("\"a\\nb\"").unwrap(),
            Value::Str("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": "x"}], "c": false}"#).unwrap();
        assert_eq!(v.get("a").at(2).get("b").as_str(), Some("x"));
        assert_eq!(v.get("c").as_bool(), Some(false));
        assert_eq!(v.get("missing"), &Value::Null);
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"name":"twophase","nums":[1,2.5,-3],"nested":{"ok":true,"nil":null}}"#;
        let v = Value::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Value::parse(&out).unwrap(), v);
    }

    #[test]
    fn roundtrip_escapes_and_unicode() {
        let v = Value::Str("tab\t quote\" slash\\ nl\n é λ".into());
        let out = v.to_string();
        assert_eq!(Value::parse(&out).unwrap(), v);
    }

    #[test]
    fn u64_accessor() {
        assert_eq!(Value::Num(42.0).as_u64(), Some(42));
        assert_eq!(Value::Num(42.5).as_u64(), None);
        assert_eq!(Value::Num(-1.0).as_u64(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Value::parse("{").is_err());
        assert!(Value::parse("[1,]").is_err());
        assert!(Value::parse("1 2").is_err());
        assert!(Value::parse("'single'").is_err());
    }

    #[test]
    fn deterministic_key_order() {
        let a = Value::parse(r#"{"z":1,"a":2}"#).unwrap().to_string();
        let b = Value::parse(r#"{"a":2,"z":1}"#).unwrap().to_string();
        assert_eq!(a, b);
    }
}
