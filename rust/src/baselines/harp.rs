//! HARP — Arslan, Guner & Kosar, SC'16 [24]: the paper's closest
//! competitor.
//!
//! HARP "uses heuristics to perform a sample transfer. Then the model
//! performs online optimization to get suitable parameters and starts
//! transferring the rest of the dataset" — per request, every time.
//! Our implementation:
//!
//! 1. three heuristic sample transfers spanning the parameter diagonal
//!    (low / BDP-scaled / high), as the published HARP probes;
//! 2. an online quadratic-regression fit over the samples (HARP's
//!    per-request optimization — the expensive step the two-phase model
//!    amortizes offline);
//! 3. argmax of the regression on the bounded grid → stream.
//!
//! HARP never re-tunes after the initial probing (§5.4: "HARP does not
//! have this ability as it sets the parameters at the beginning").

use crate::baselines::api::Optimizer;
use crate::offline::regression::{Degree, PolySurface};
use crate::sim::dataset::Dataset;
use crate::sim::profile::NetProfile;
use crate::Params;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum HarpPhase {
    Probing(usize),
    Streaming,
}

#[derive(Debug, Clone)]
pub struct Harp {
    probes: Vec<Params>,
    observations: Vec<(Params, f64)>,
    phase: HarpPhase,
    chosen: Params,
    predicted: Option<f64>,
    max_param: u32,
}

impl Harp {
    pub fn plan(profile: &NetProfile, dataset: &Dataset) -> Harp {
        let cap = profile.max_param;
        let bdp_mb = profile.bdp_mb().max(0.05);
        // heuristic probe ladder: conservative, BDP-informed, aggressive
        let mid_p = ((bdp_mb / dataset.avg_file_mb).ceil() as u32).clamp(1, cap / 2);
        let mid_cc = ((dataset.n_files as f64 / 128.0).ceil() as u32).clamp(2, cap / 2);
        let pp = if dataset.avg_file_mb < 10.0 { 16 } else { 4 };
        let probes = vec![
            Params::new(2, 1, pp),
            Params::new(mid_cc, mid_p.max(2), pp),
            Params::new((mid_cc * 4).min(cap), (mid_p * 4).clamp(2, cap), pp),
        ];
        Harp {
            chosen: probes[0],
            probes,
            observations: Vec::new(),
            phase: HarpPhase::Probing(0),
            predicted: None,
            max_param: cap,
        }
    }

    /// The regression fit + argmax (HARP's online optimization step).
    fn optimize(&mut self) {
        // quadratic needs >= 10 coefficients; with 3 probes the ridge
        // term in `least_squares` keeps it solvable, matching HARP's
        // reduced quadratic (it fixes cross terms with few samples).
        if let Some(m) = PolySurface::fit(Degree::Quadratic, &self.observations) {
            let (best, pred) = m.argmax_on_grid(self.max_param);
            self.chosen = best;
            self.predicted = Some(pred);
        } else if let Some((best, th)) = self
            .observations
            .iter()
            .max_by(|a, b| a.1.total_cmp(&b.1))
        {
            self.chosen = *best;
            self.predicted = Some(*th);
        }
    }
}

impl Optimizer for Harp {
    fn name(&self) -> &'static str {
        "HARP"
    }

    fn next_params(&mut self, last_th: Option<f64>) -> Params {
        match self.phase {
            HarpPhase::Probing(i) => {
                if let Some(th) = last_th {
                    if i > 0 {
                        self.observations.push((self.probes[i - 1], th));
                    }
                }
                if i < self.probes.len() {
                    self.phase = HarpPhase::Probing(i + 1);
                    self.probes[i]
                } else {
                    self.optimize();
                    self.phase = HarpPhase::Streaming;
                    self.chosen
                }
            }
            HarpPhase::Streaming => {
                // collect the final probe's observation exactly once
                if self.observations.len() < self.probes.len() {
                    if let Some(th) = last_th {
                        self.observations.push((self.probes[self.probes.len() - 1], th));
                        self.optimize();
                    }
                }
                self.chosen
            }
        }
    }

    fn predicted_th(&self) -> Option<f64> {
        self.predicted
    }

    fn samples_used(&self) -> usize {
        self.observations.len().min(self.probes.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn harp() -> Harp {
        Harp::plan(&NetProfile::xsede(), &Dataset::new(256, 512.0))
    }

    #[test]
    fn probes_then_streams() {
        let mut h = harp();
        let p1 = h.next_params(None);
        let p2 = h.next_params(Some(100.0));
        let p3 = h.next_params(Some(400.0));
        assert_ne!(p1, p3);
        // 4th call: optimization happened, streaming begins
        let p4 = h.next_params(Some(900.0));
        let p5 = h.next_params(Some(900.0));
        // once the final probe's observation lands, the choice is fixed
        let p6 = h.next_params(Some(900.0));
        assert_eq!(p5, p6);
        let _ = (p2, p4);
        assert_eq!(h.samples_used(), 3);
        assert!(h.predicted_th().is_some());
    }

    #[test]
    fn picks_high_stream_params_when_throughput_rises_with_streams() {
        let mut h = harp();
        let probes = h.probes.clone();
        let th = |q: Params| 100.0 * (q.total_streams() as f64).sqrt();
        h.next_params(None);
        h.next_params(Some(th(probes[0])));
        h.next_params(Some(th(probes[1])));
        h.next_params(Some(th(probes[2])));
        let chosen = h.next_params(Some(0.0));
        assert!(
            chosen.total_streams() >= probes[1].total_streams(),
            "chosen {chosen}"
        );
    }

    #[test]
    fn never_retunes_after_streaming() {
        let mut h = harp();
        for th in [Some(500.0), Some(600.0), Some(700.0), Some(650.0)] {
            h.next_params(th);
        }
        let chosen = h.next_params(Some(650.0));
        // feed wildly different throughputs: HARP must not move
        for th in [10.0, 10_000.0, 1.0] {
            assert_eq!(h.next_params(Some(th)), chosen);
        }
    }

    #[test]
    fn probe_ladder_is_increasing() {
        let h = harp();
        assert!(h.probes[0].total_streams() < h.probes[2].total_streams());
        for p in &h.probes {
            assert!(p.cc <= 32 && p.p <= 32);
        }
    }
}
