//! NMT — the Nelder–Mead direct-search tuner of Balaprakash et al.,
//! ICPP'16 [25].
//!
//! "Nelder-Mead Tuner implements a direct search optimization which
//! does not consider any historical analysis, rather tries to reach
//! [the] optimal point using reflection and expansion operation" (§5).
//! Each simplex evaluation is a real chunk transfer, so convergence
//! burns wall-clock ("some cases it requires 16-20 epochs to converge
//! which could lead to under-utilization", §6).
//!
//! Standard Nelder–Mead in continuous (cc, p, pp) space (α = 1, γ = 2,
//! ρ = ½, σ = ½), rounded to the integer grid per evaluation, with an
//! evaluation budget after which the best vertex streams.

use crate::baselines::api::Optimizer;
use crate::Params;

type Point = [f64; 3];

fn to_params(x: &Point, cap: u32) -> Params {
    Params::new(
        x[0].round().clamp(1.0, cap as f64) as u32,
        x[1].round().clamp(1.0, cap as f64) as u32,
        x[2].round().clamp(1.0, cap as f64) as u32,
    )
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum NmState {
    /// evaluating initial simplex vertex i
    Init(usize),
    /// waiting for the reflection point's value
    Reflect,
    /// waiting for the expansion point's value
    Expand,
    /// waiting for the contraction point's value
    Contract,
    /// shrinking: re-evaluating vertex i (1..=3)
    Shrink(usize),
    /// converged / budget exhausted: streaming at the best vertex
    Done,
}

/// Nelder–Mead over live chunk transfers.
#[derive(Debug, Clone)]
pub struct NelderMead {
    simplex: [Point; 4],
    values: [f64; 4],
    state: NmState,
    /// the point whose measured value we are waiting for
    pending: Point,
    /// reflection value cache (needed when deciding expansion)
    reflect_cache: (Point, f64),
    evals: usize,
    max_evals: usize,
    cap: u32,
}

impl NelderMead {
    pub fn new(start: Params, cap: u32, max_evals: usize) -> NelderMead {
        let s0 = [start.cc as f64, start.p as f64, start.pp as f64];
        // initial simplex: start + unit-ish steps per dimension
        let mut simplex = [s0; 4];
        for d in 0..3 {
            simplex[d + 1][d] = (s0[d] * 2.0).clamp(1.0, cap as f64).max(s0[d] + 1.0);
        }
        NelderMead {
            simplex,
            values: [f64::NEG_INFINITY; 4],
            state: NmState::Init(0),
            pending: simplex[0],
            reflect_cache: (s0, f64::NEG_INFINITY),
            evals: 0,
            max_evals,
            cap,
        }
    }

    fn order(&mut self) {
        // sort vertices by value descending (we maximize)
        let mut idx = [0usize, 1, 2, 3];
        idx.sort_by(|&a, &b| self.values[b].total_cmp(&self.values[a]));
        self.simplex = idx.map(|i| self.simplex[i]);
        self.values = idx.map(|i| self.values[i]);
    }

    fn centroid_best3(&self) -> Point {
        let mut c = [0.0; 3];
        for v in &self.simplex[..3] {
            for d in 0..3 {
                c[d] += v[d] / 3.0;
            }
        }
        c
    }

    fn propose_reflection(&mut self) -> Point {
        let c = self.centroid_best3();
        let w = self.simplex[3];
        let mut r = [0.0; 3];
        for d in 0..3 {
            r[d] = (c[d] + (c[d] - w[d])).clamp(1.0, self.cap as f64);
        }
        r
    }

    fn best_params(&self) -> Params {
        to_params(&self.simplex[0], self.cap)
    }
}

impl Optimizer for NelderMead {
    fn name(&self) -> &'static str {
        "NMT"
    }

    fn next_params(&mut self, last_th: Option<f64>) -> Params {
        // record the pending evaluation
        if let Some(th) = last_th {
            self.evals += 1;
            match self.state {
                NmState::Init(i) => {
                    self.values[i] = th;
                    if i + 1 < 4 {
                        self.state = NmState::Init(i + 1);
                        self.pending = self.simplex[i + 1];
                    } else {
                        self.order();
                        self.state = NmState::Reflect;
                        self.pending = self.propose_reflection();
                    }
                }
                NmState::Reflect => {
                    let r = self.pending;
                    if th > self.values[0] {
                        // try expansion
                        self.reflect_cache = (r, th);
                        let c = self.centroid_best3();
                        let mut e = [0.0; 3];
                        for d in 0..3 {
                            e[d] = (c[d] + 2.0 * (r[d] - c[d])).clamp(1.0, self.cap as f64);
                        }
                        self.state = NmState::Expand;
                        self.pending = e;
                    } else if th > self.values[2] {
                        // accept reflection
                        self.simplex[3] = r;
                        self.values[3] = th;
                        self.order();
                        self.state = NmState::Reflect;
                        self.pending = self.propose_reflection();
                    } else {
                        // contract towards the centroid
                        self.reflect_cache = (r, th);
                        let c = self.centroid_best3();
                        let w = self.simplex[3];
                        let mut k = [0.0; 3];
                        for d in 0..3 {
                            k[d] = (c[d] + 0.5 * (w[d] - c[d])).clamp(1.0, self.cap as f64);
                        }
                        self.state = NmState::Contract;
                        self.pending = k;
                    }
                }
                NmState::Expand => {
                    let (r, rv) = self.reflect_cache;
                    if th > rv {
                        self.simplex[3] = self.pending;
                        self.values[3] = th;
                    } else {
                        self.simplex[3] = r;
                        self.values[3] = rv;
                    }
                    self.order();
                    self.state = NmState::Reflect;
                    self.pending = self.propose_reflection();
                }
                NmState::Contract => {
                    let (_, rv) = self.reflect_cache;
                    if th > rv.max(self.values[3]) {
                        self.simplex[3] = self.pending;
                        self.values[3] = th;
                        self.order();
                        self.state = NmState::Reflect;
                        self.pending = self.propose_reflection();
                    } else {
                        // shrink towards the best vertex
                        for i in 1..4 {
                            for d in 0..3 {
                                self.simplex[i][d] = (self.simplex[0][d]
                                    + 0.5 * (self.simplex[i][d] - self.simplex[0][d]))
                                    .clamp(1.0, self.cap as f64);
                            }
                        }
                        self.state = NmState::Shrink(1);
                        self.pending = self.simplex[1];
                    }
                }
                NmState::Shrink(i) => {
                    self.values[i] = th;
                    if i + 1 < 4 {
                        self.state = NmState::Shrink(i + 1);
                        self.pending = self.simplex[i + 1];
                    } else {
                        self.order();
                        self.state = NmState::Reflect;
                        self.pending = self.propose_reflection();
                    }
                }
                NmState::Done => {}
            }
        }

        // budget / degenerate-simplex stopping rule
        if self.state != NmState::Done {
            let spread = self.values[0] - self.values[3];
            let converged = self.evals >= 4
                && spread.is_finite()
                && spread.abs() < 0.01 * self.values[0].abs().max(1.0);
            if self.evals >= self.max_evals || converged {
                self.state = NmState::Done;
            }
        }

        match self.state {
            NmState::Done => self.best_params(),
            _ => to_params(&self.pending, self.cap),
        }
    }

    fn predicted_th(&self) -> Option<f64> {
        if self.values[0].is_finite() {
            Some(self.values[0])
        } else {
            None
        }
    }

    fn samples_used(&self) -> usize {
        self.evals.min(self.max_evals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Concave test function peaking at (12, 6, 10).
    fn peak(q: Params) -> f64 {
        1_000.0
            - 3.0 * (q.cc as f64 - 12.0).powi(2)
            - 5.0 * (q.p as f64 - 6.0).powi(2)
            - 1.0 * (q.pp as f64 - 10.0).powi(2)
    }

    fn run(mut nm: NelderMead, evals: usize) -> (Params, usize) {
        let mut q = nm.next_params(None);
        for _ in 0..evals {
            q = nm.next_params(Some(peak(q)));
        }
        (q, nm.samples_used())
    }

    #[test]
    fn climbs_towards_the_peak() {
        let nm = NelderMead::new(Params::new(2, 2, 2), 32, 40);
        let start_v = peak(Params::new(2, 2, 2));
        let (q, _) = run(nm, 40);
        assert!(
            peak(q) > start_v + 100.0,
            "no progress: started {start_v}, ended {} at {q}",
            peak(q)
        );
    }

    #[test]
    fn stops_at_eval_budget() {
        let nm = NelderMead::new(Params::new(2, 2, 2), 32, 10);
        let mut nm2 = nm.clone();
        let mut q = nm2.next_params(None);
        for _ in 0..30 {
            q = nm2.next_params(Some(peak(q)));
        }
        assert!(nm2.samples_used() <= 10);
        // after the budget the params freeze
        let frozen = nm2.next_params(Some(1.0));
        assert_eq!(frozen, nm2.next_params(Some(1e9)));
        let _ = q;
    }

    #[test]
    fn params_always_in_domain() {
        let mut nm = NelderMead::new(Params::new(31, 31, 31), 32, 30);
        let mut q = nm.next_params(None);
        for _ in 0..30 {
            assert!((1..=32).contains(&q.cc), "{q}");
            assert!((1..=32).contains(&q.p));
            assert!((1..=32).contains(&q.pp));
            q = nm.next_params(Some(peak(q)));
        }
    }

    #[test]
    fn converges_on_flat_function() {
        // constant throughput: simplex spread hits the tolerance fast
        let mut nm = NelderMead::new(Params::new(4, 4, 4), 32, 40);
        let mut q = nm.next_params(None);
        let mut used = 0;
        for _ in 0..40 {
            q = nm.next_params(Some(500.0));
            used = nm.samples_used();
            if matches!(nm.state, NmState::Done) {
                break;
            }
        }
        assert!(used <= 8, "flat function should converge quickly: {used}");
        let _ = q;
    }
}
