//! The uniform optimizer interface every §5 model implements.

use crate::online::controller::DynamicTuner;
use crate::sim::multiuser::{UserCtx, UserPolicy};
use crate::Params;

/// A transfer-parameter optimizer driving one transfer.
///
/// The engine calls [`Optimizer::next_params`] before every chunk with
/// the previous chunk's measured throughput (None before the first).
pub trait Optimizer {
    fn name(&self) -> &'static str;

    fn next_params(&mut self, last_th: Option<f64>) -> Params;

    /// The model's own prediction of achievable throughput at its
    /// current parameters, if it makes one (Fig 8 accuracy metric).
    fn predicted_th(&self) -> Option<f64> {
        None
    }

    /// Number of dedicated sample transfers the model has consumed.
    fn samples_used(&self) -> usize {
        0
    }

    /// Converged operating point worth memoizing in the coordinator's
    /// historical tuning cache, if the model has one.  Only the ASM
    /// implements this (it is the model whose probing the cache
    /// short-circuits); baselines return None.
    fn cache_entry(&self) -> Option<crate::offline::cache::CachedTuning> {
        None
    }

    /// Drain trace events minted since the last call (sampling steps,
    /// convergence, alarm transitions, re-tunes).  The model has no
    /// clock; the engine stamps the events with the sim time of the
    /// chunk that produced them.  Baselines trace nothing.
    fn drain_trace(&mut self) -> Vec<crate::util::trace::PendingEvent> {
        Vec::new()
    }
}

/// Identifier for the seven evaluated models (drives the Fig 5 matrix).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimizerKind {
    Asm,
    Harp,
    AnnOt,
    Globus,
    StaticAnn,
    SingleChunk,
    NelderMead,
    NoOpt,
}

impl OptimizerKind {
    pub fn label(&self) -> &'static str {
        match self {
            Self::Asm => "ASM",
            Self::Harp => "HARP",
            Self::AnnOt => "ANN+OT",
            Self::Globus => "GO",
            Self::StaticAnn => "SP",
            Self::SingleChunk => "SC",
            Self::NelderMead => "NMT",
            Self::NoOpt => "NoOpt",
        }
    }

    pub fn all() -> [OptimizerKind; 8] {
        [
            Self::Asm,
            Self::Harp,
            Self::AnnOt,
            Self::Globus,
            Self::StaticAnn,
            Self::SingleChunk,
            Self::NelderMead,
            Self::NoOpt,
        ]
    }
}

/// The §5.4 "No Optimization" baseline: cc = p = pp = 1 forever.
#[derive(Debug, Default)]
pub struct NoOptimization;

impl Optimizer for NoOptimization {
    fn name(&self) -> &'static str {
        "NoOpt"
    }

    fn next_params(&mut self, _last_th: Option<f64>) -> Params {
        Params::DEFAULT
    }
}

/// Our model behind the same interface (wraps the online controller).
pub struct AsmOptimizer {
    pub tuner: DynamicTuner,
}

impl AsmOptimizer {
    pub fn new(tuner: DynamicTuner) -> AsmOptimizer {
        AsmOptimizer { tuner }
    }
}

impl Optimizer for AsmOptimizer {
    fn name(&self) -> &'static str {
        "ASM"
    }

    fn next_params(&mut self, last_th: Option<f64>) -> Params {
        match last_th {
            None => self.tuner.params(),
            Some(th) => self.tuner.observe(th),
        }
    }

    fn predicted_th(&self) -> Option<f64> {
        Some(self.tuner.predicted())
    }

    fn samples_used(&self) -> usize {
        self.tuner.samples_used()
    }

    fn cache_entry(&self) -> Option<crate::offline::cache::CachedTuning> {
        use crate::online::asm::AsmPhase;
        if self.tuner.phase() != AsmPhase::Streaming {
            return None;
        }
        Some(crate::offline::cache::CachedTuning {
            params: self.tuner.params(),
            predicted_mbps: self.tuner.predicted(),
            bucket: self.tuner.asm().current_bucket(),
        })
    }

    fn drain_trace(&mut self) -> Vec<crate::util::trace::PendingEvent> {
        self.tuner.drain_trace()
    }
}

/// Adapter: any Optimizer is a multi-user policy.
pub struct PolicyAdapter<O: Optimizer>(pub O);

impl<O: Optimizer> UserPolicy for PolicyAdapter<O> {
    fn decide(&mut self, ctx: &UserCtx) -> Params {
        self.0.next_params(ctx.last_throughput)
    }

    fn name(&self) -> &str {
        self.0.name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noopt_is_all_ones() {
        let mut o = NoOptimization;
        assert_eq!(o.next_params(None), Params::DEFAULT);
        assert_eq!(o.next_params(Some(123.0)), Params::DEFAULT);
        assert_eq!(o.predicted_th(), None);
    }

    #[test]
    fn kind_labels_unique() {
        let labels: Vec<&str> = OptimizerKind::all().iter().map(|k| k.label()).collect();
        let mut dedup = labels.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), labels.len());
    }
}
