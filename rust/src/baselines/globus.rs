//! GO — the Globus Online static baseline [21].
//!
//! Globus picks fixed parameter sets keyed on dataset file-size class
//! ("Globus uses different static parameter settings for different
//! types of file sizes", §5) — no network awareness, no adaptation.
//! Values follow the published Globus transfer presets: modest
//! concurrency, pipelining for lots of small files, parallelism for
//! big ones.

use crate::baselines::api::Optimizer;
use crate::sim::dataset::{Dataset, FileSizeClass};
use crate::Params;

#[derive(Debug, Clone)]
pub struct Globus {
    params: Params,
}

impl Globus {
    pub fn for_dataset(dataset: &Dataset) -> Globus {
        let params = match dataset.class() {
            // many small files: pipeline hard, two concurrent channels
            FileSizeClass::Small => Params::new(2, 1, 20),
            // the middle preset
            FileSizeClass::Medium => Params::new(4, 2, 5),
            // few big files: parallel streams
            FileSizeClass::Large => Params::new(2, 4, 2),
        };
        Globus { params }
    }
}

impl Optimizer for Globus {
    fn name(&self) -> &'static str {
        "GO"
    }

    fn next_params(&mut self, _last_th: Option<f64>) -> Params {
        self.params
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_presets() {
        let mut small = Globus::for_dataset(&Dataset::new(10_000, 1.0));
        assert_eq!(small.next_params(None).pp, 20);
        let mut large = Globus::for_dataset(&Dataset::new(16, 2_048.0));
        assert_eq!(large.next_params(None).p, 4);
    }

    #[test]
    fn static_regardless_of_feedback() {
        let mut g = Globus::for_dataset(&Dataset::new(100, 100.0));
        let a = g.next_params(None);
        let b = g.next_params(Some(1.0));
        let c = g.next_params(Some(1e6));
        assert_eq!(a, b);
        assert_eq!(b, c);
    }
}
