//! SC — the Single-Chunk heuristic of Arslan et al. [23].
//!
//! Parameters follow closed-form rules over dataset characteristics and
//! network metrics ("SC also makes parameter decision based on dataset
//! characteristics and network matrices"), bounded by a user-supplied
//! concurrency limit ("It asks the user to provide an upper limit for
//! concurrency value. SC does not exceed that limit", §5):
//!
//! * parallelism covers the BDP with one file's worth of data per
//!   stream: `p ≈ BDP / f_avg`;
//! * pipelining hides one RTT of control traffic per file:
//!   `pp ≈ BDP / f_avg` for small files;
//! * concurrency grows with file count up to the user cap.

use crate::baselines::api::Optimizer;
use crate::sim::dataset::Dataset;
use crate::sim::profile::NetProfile;
use crate::Params;

#[derive(Debug, Clone)]
pub struct SingleChunk {
    params: Params,
}

impl SingleChunk {
    pub fn plan(profile: &NetProfile, dataset: &Dataset, user_cc_cap: u32) -> SingleChunk {
        let bdp_mb = profile.bdp_mb().max(0.05);
        let f = dataset.avg_file_mb;

        let p = ((bdp_mb / f).ceil() as u32).clamp(1, profile.max_param.min(8));
        let pp = ((bdp_mb / f).ceil() as u32).clamp(1, profile.max_param);
        // one channel per ~64 files, capped by the user limit
        let cc = ((dataset.n_files as f64 / 64.0).ceil() as u32)
            .clamp(1, user_cc_cap.min(profile.max_param));
        SingleChunk {
            params: Params::new(cc, p, pp),
        }
    }
}

impl Optimizer for SingleChunk {
    fn name(&self) -> &'static str {
        "SC"
    }

    fn next_params(&mut self, _last_th: Option<f64>) -> Params {
        self.params
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_files_get_pipelining_not_parallelism() {
        let p = NetProfile::xsede(); // BDP 50 MB
        let sc = SingleChunk::plan(&p, &Dataset::new(50_000, 1.0), 16);
        let q = sc.clone().next_params(None);
        assert!(q.pp >= 16, "{q}");
        assert!(q.p <= 8);
        assert_eq!(q.cc, 16, "hits the user cap");
    }

    #[test]
    fn large_files_get_parallelism() {
        let p = NetProfile::xsede();
        let sc = SingleChunk::plan(&p, &Dataset::new(16, 4_096.0), 16);
        let q = sc.clone().next_params(None);
        assert_eq!(q.p, 1, "one 4 GB file covers the BDP alone");
        assert_eq!(q.pp, 1);
        assert_eq!(q.cc, 1);
    }

    #[test]
    fn respects_user_cc_cap() {
        let p = NetProfile::xsede();
        let sc = SingleChunk::plan(&p, &Dataset::new(100_000, 1.0), 4);
        assert_eq!(sc.clone().next_params(None).cc, 4);
    }

    #[test]
    fn short_rtt_path_needs_few_streams() {
        let p = NetProfile::didclab(); // BDP 25 KB
        let sc = SingleChunk::plan(&p, &Dataset::new(100, 100.0), 8);
        let q = sc.clone().next_params(None);
        assert_eq!(q.p, 1);
    }
}
