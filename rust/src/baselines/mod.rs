//! The comparison models of §5: two static models (GO, SP), one
//! heuristic (SC), two dynamic models (HARP, ANN+OT) and one
//! mathematical direct-search model (NMT), all behind the
//! [`api::Optimizer`] trait so the experiment drivers treat every model
//! — including our ASM — uniformly.

pub mod ann_ot;
pub mod api;
pub mod globus;
pub mod harp;
pub mod mlp;
pub mod nelder_mead;
pub mod single_chunk;
pub mod static_ann;

pub use api::{AsmOptimizer, NoOptimization, Optimizer, OptimizerKind};
