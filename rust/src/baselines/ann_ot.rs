//! ANN+OT — the historical-ANN + online-tuning model of [22].
//!
//! "ANN+OT learns the throughput for each transfer request from the
//! historical logs.  When a new transfer request comes, [the] model
//! asks the machine learning module for suitable parameters to perform
//! [the] first sample transfer.  Then it uses recent transfer history
//! to model the current load and tune the parameters accordingly.  The
//! model only relies on historical data and always tends to choose the
//! local maxima from historical log rather than the global one" (§5).
//!
//! Implementation: an MLP is trained on the corpus to predict
//! *throughput* from (context, params); the initial parameters are the
//! argmax of that predictor over the historically-tried parameter set
//! (hence "local maxima from historical log"); online, a one-step
//! hill climber nudges one parameter per chunk, keeping changes that
//! helped and reverting ones that hurt.

use crate::baselines::api::Optimizer;
use crate::baselines::mlp::Mlp;
use crate::logs::schema::LogEntry;
use crate::offline::features::{raw_features, FeatureScaler};
use crate::util::rng::Rng;
use crate::Params;

/// Trained throughput predictor shared by ANN+OT transfers.
#[derive(Debug, Clone)]
pub struct AnnOtModel {
    scaler: FeatureScaler,
    net: Mlp,
    /// parameter combinations present in the corpus ("historical" set)
    tried_params: Vec<Params>,
    th_scale: f64,
    max_param: u32,
}

impl AnnOtModel {
    pub fn train(entries: &[LogEntry], max_param: u32, seed: u64) -> AnnOtModel {
        assert!(!entries.is_empty());
        let refs: Vec<&LogEntry> = entries.iter().collect();
        let scaler = FeatureScaler::fit(&refs);
        let th_scale = entries
            .iter()
            .map(|e| e.throughput_mbps)
            .fold(0.0, f64::max)
            .max(1.0);
        let cap = max_param as f64;

        let mut tried: Vec<Params> = Vec::new();
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for e in entries {
            if !tried.contains(&e.params) {
                tried.push(e.params);
            }
            let mut x = scaler.apply(raw_features(e)).to_vec();
            x.extend_from_slice(&[
                e.params.cc as f64 / cap,
                e.params.p as f64 / cap,
                e.params.pp as f64 / cap,
            ]);
            xs.push(x);
            ys.push(vec![e.throughput_mbps / th_scale]);
        }
        let mut rng = Rng::new(seed ^ 0xA007);
        let mut net = Mlp::new(&[7, 24, 12, 1], &mut rng);
        net.fit(&xs, &ys, 60, 0.02, &mut rng);
        AnnOtModel {
            scaler,
            net,
            tried_params: tried,
            th_scale,
            max_param,
        }
    }

    /// Predicted throughput (Mbps) for a context + parameter choice.
    pub fn predict_th(
        &self,
        rtt_s: f64,
        bw: f64,
        favg: f64,
        nf: u64,
        params: Params,
    ) -> f64 {
        let cap = self.max_param as f64;
        let mut x = self.scaler.transform_query(rtt_s, bw, favg, nf).to_vec();
        x.extend_from_slice(&[
            params.cc as f64 / cap,
            params.p as f64 / cap,
            params.pp as f64 / cap,
        ]);
        (self.net.predict(&x)[0] * self.th_scale).max(0.0)
    }

    /// Best historically-tried parameters for a context.
    pub fn best_historical(&self, rtt_s: f64, bw: f64, favg: f64, nf: u64) -> (Params, f64) {
        let mut best = (Params::DEFAULT, f64::NEG_INFINITY);
        for &q in &self.tried_params {
            let v = self.predict_th(rtt_s, bw, favg, nf, q);
            if v > best.1 {
                best = (q, v);
            }
        }
        best
    }
}

/// Per-transfer ANN+OT optimizer.
pub struct AnnOt {
    params: Params,
    predicted: f64,
    /// (previous params, previous throughput) for the hill climber
    last: Option<(Params, f64)>,
    /// dimension to nudge next (cycles cc -> p -> pp)
    dim: usize,
    /// +1 or -1 direction currently being explored
    dir: i64,
    max_param: u32,
    rng: Rng,
}

impl AnnOt {
    pub fn for_transfer(
        model: &AnnOtModel,
        rtt_s: f64,
        bw: f64,
        favg: f64,
        nf: u64,
        seed: u64,
    ) -> AnnOt {
        let (params, predicted) = model.best_historical(rtt_s, bw, favg, nf);
        AnnOt {
            params,
            predicted,
            last: None,
            dim: 0,
            dir: 1,
            max_param: model.max_param,
            rng: Rng::new(seed ^ 0x07),
        }
    }

    fn nudge(&self, q: Params, dim: usize, dir: i64) -> Params {
        let step = |v: u32| -> u32 {
            let stepped = v as i64 + dir * (v as i64 / 4).max(1);
            stepped.clamp(1, self.max_param as i64) as u32
        };
        match dim {
            0 => Params::new(step(q.cc), q.p, q.pp),
            1 => Params::new(q.cc, step(q.p), q.pp),
            _ => Params::new(q.cc, q.p, step(q.pp)),
        }
    }
}

impl Optimizer for AnnOt {
    fn name(&self) -> &'static str {
        "ANN+OT"
    }

    fn next_params(&mut self, last_th: Option<f64>) -> Params {
        let Some(th) = last_th else {
            return self.params; // first sample transfer at the ANN pick
        };
        match self.last.take() {
            None => {
                // first feedback: record base point, try a nudge
                self.last = Some((self.params, th));
                self.params = self.nudge(self.params, self.dim, self.dir);
                self.params
            }
            Some((prev_params, prev_th)) => {
                if th >= prev_th * 1.02 {
                    // improvement: keep going in this direction
                    self.last = Some((self.params, th));
                    self.params = self.nudge(self.params, self.dim, self.dir);
                } else {
                    // no improvement: revert, rotate dimension/direction
                    self.params = prev_params;
                    self.dim = (self.dim + 1) % 3;
                    if self.dim == 0 {
                        self.dir = -self.dir;
                    }
                    self.last = Some((self.params, prev_th.max(th)));
                    // occasionally probe anyway to track load changes
                    if self.rng.chance(0.5) {
                        self.params = self.nudge(self.params, self.dim, self.dir);
                    }
                }
                self.params
            }
        }
    }

    fn predicted_th(&self) -> Option<f64> {
        Some(self.predicted)
    }

    fn samples_used(&self) -> usize {
        1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logs::generator::{generate_history, GeneratorConfig};
    use crate::sim::profile::NetProfile;

    fn model() -> &'static AnnOtModel {
        use std::sync::OnceLock;
        static MODEL: OnceLock<AnnOtModel> = OnceLock::new();
        MODEL.get_or_init(|| {
            let logs = generate_history(
                &NetProfile::xsede(),
                &GeneratorConfig {
                    days: 10.0,
                    transfers_per_hour: 10.0,
                    seed: 21,
                },
            );
            AnnOtModel::train(&logs, 32, 1)
        })
    }

    #[test]
    fn initial_pick_is_historical() {
        let m: &AnnOtModel = model();
        let (q, pred) = m.best_historical(0.04, 10_000.0, 512.0, 128);
        assert!(m.tried_params.contains(&q));
        assert!(pred > 0.0);
    }

    #[test]
    fn predictor_learns_stream_benefit() {
        // on XSEDE large files, 16 streams should predict much better
        // than a single stream
        let m: &AnnOtModel = model();
        let lo = m.predict_th(0.04, 10_000.0, 1_024.0, 64, Params::new(1, 1, 4));
        let hi = m.predict_th(0.04, 10_000.0, 1_024.0, 64, Params::new(8, 4, 4));
        assert!(hi > lo * 1.5, "lo={lo} hi={hi}");
    }

    #[test]
    fn hill_climber_keeps_improvements_and_reverts_regressions() {
        let m: &AnnOtModel = model();
        let mut ot = AnnOt::for_transfer(&m, 0.04, 10_000.0, 512.0, 128, 3);
        let p0 = ot.next_params(None);
        // feed a throughput function that punishes any move away from p0
        let th = |q: Params| if q == p0 { 1_000.0 } else { 10.0 };
        let mut current = ot.next_params(Some(th(p0)));
        let mut at_base = 0;
        for _ in 0..40 {
            current = ot.next_params(Some(th(current)));
            if current == p0 {
                at_base += 1;
            }
        }
        // the climber re-probes ~50% of the time even at the base, so
        // expect to sit at the base roughly half the steps
        assert!(at_base >= 12, "should keep returning to base: {at_base}/40");
    }

    #[test]
    fn climbs_towards_better_stream_counts() {
        // start from an explicitly low point so there is room to climb
        let mut ot = AnnOt {
            params: Params::new(2, 2, 4),
            predicted: 100.0,
            last: None,
            dim: 0,
            dir: 1,
            max_param: 32,
            rng: Rng::new(4),
        };
        let start = ot.next_params(None);
        // reward more total streams, uncapped within the domain
        let th = |q: Params| 100.0 * q.total_streams() as f64;
        let mut current = ot.next_params(Some(th(start)));
        for _ in 0..30 {
            current = ot.next_params(Some(th(current)));
        }
        assert!(
            current.total_streams() > start.total_streams(),
            "{start} -> {current}"
        );
    }
}
