//! A minimal multilayer perceptron with SGD training — the substrate
//! for the Static-ANN (SP) and ANN+OT baselines [22].  tanh hidden
//! layers, linear output, mean-squared-error loss, no external deps.

use crate::util::rng::Rng;

/// Fully-connected feed-forward network.
#[derive(Debug, Clone)]
pub struct Mlp {
    /// layer sizes, e.g. [4, 16, 8, 3]
    pub sizes: Vec<usize>,
    /// weights[l][i][j]: layer l, output unit i, input unit j
    weights: Vec<Vec<Vec<f64>>>,
    biases: Vec<Vec<f64>>,
}

impl Mlp {
    /// Xavier-ish random initialization.
    pub fn new(sizes: &[usize], rng: &mut Rng) -> Mlp {
        assert!(sizes.len() >= 2);
        let mut weights = Vec::new();
        let mut biases = Vec::new();
        for l in 0..sizes.len() - 1 {
            let (fan_in, fan_out) = (sizes[l], sizes[l + 1]);
            let scale = (2.0 / (fan_in + fan_out) as f64).sqrt();
            weights.push(
                (0..fan_out)
                    .map(|_| (0..fan_in).map(|_| rng.normal() * scale).collect())
                    .collect(),
            );
            biases.push(vec![0.0; fan_out]);
        }
        Mlp {
            sizes: sizes.to_vec(),
            weights,
            biases,
        }
    }

    /// Forward pass returning all layer activations (post-nonlinearity).
    fn forward_full(&self, x: &[f64]) -> Vec<Vec<f64>> {
        assert_eq!(x.len(), self.sizes[0]);
        let mut acts = vec![x.to_vec()];
        let last = self.weights.len() - 1;
        for (l, (w, b)) in self.weights.iter().zip(&self.biases).enumerate() {
            let prev = &acts[l];
            let mut z: Vec<f64> = w
                .iter()
                .zip(b)
                .map(|(row, bias)| {
                    row.iter().zip(prev).map(|(wi, xi)| wi * xi).sum::<f64>() + bias
                })
                .collect();
            if l != last {
                for v in &mut z {
                    *v = v.tanh();
                }
            }
            acts.push(z);
        }
        acts
    }

    pub fn predict(&self, x: &[f64]) -> Vec<f64> {
        self.forward_full(x).pop().unwrap_or_default()
    }

    /// One SGD step on a single example; returns the example's MSE.
    pub fn train_step(&mut self, x: &[f64], y: &[f64], lr: f64) -> f64 {
        let acts = self.forward_full(x);
        let out = &acts[self.weights.len()];
        assert_eq!(y.len(), out.len());
        // output delta (linear output, MSE): dL/dz = (out - y)
        let mut delta: Vec<f64> = out.iter().zip(y).map(|(o, t)| o - t).collect();
        let loss: f64 =
            delta.iter().map(|d| d * d).sum::<f64>() / (2.0 * delta.len() as f64);

        for l in (0..self.weights.len()).rev() {
            let input = &acts[l];
            // gradient step for this layer
            let prev_delta: Vec<f64> = if l > 0 {
                // backprop through weights then tanh'
                (0..self.sizes[l])
                    .map(|j| {
                        let s: f64 = (0..self.sizes[l + 1])
                            .map(|i| self.weights[l][i][j] * delta[i])
                            .sum();
                        let a = acts[l][j];
                        s * (1.0 - a * a)
                    })
                    .collect()
            } else {
                Vec::new()
            };
            for i in 0..self.sizes[l + 1] {
                for j in 0..self.sizes[l] {
                    self.weights[l][i][j] -= lr * delta[i] * input[j];
                }
                self.biases[l][i] -= lr * delta[i];
            }
            delta = prev_delta;
        }
        loss
    }

    /// Epoch-based training over a dataset; returns final mean loss.
    pub fn fit(
        &mut self,
        xs: &[Vec<f64>],
        ys: &[Vec<f64>],
        epochs: usize,
        lr: f64,
        rng: &mut Rng,
    ) -> f64 {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty());
        let mut order: Vec<usize> = (0..xs.len()).collect();
        let mut last = f64::INFINITY;
        for _ in 0..epochs {
            rng.shuffle(&mut order);
            let mut total = 0.0;
            for &i in &order {
                total += self.train_step(&xs[i], &ys[i], lr);
            }
            last = total / xs.len() as f64;
        }
        last
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fits_linear_function() {
        let mut rng = Rng::new(1);
        let mut net = Mlp::new(&[2, 8, 1], &mut rng);
        let xs: Vec<Vec<f64>> = (0..200)
            .map(|_| vec![rng.uniform(-1.0, 1.0), rng.uniform(-1.0, 1.0)])
            .collect();
        let ys: Vec<Vec<f64>> = xs.iter().map(|x| vec![0.5 * x[0] - 0.3 * x[1]]).collect();
        let loss = net.fit(&xs, &ys, 200, 0.05, &mut rng);
        assert!(loss < 1e-3, "loss={loss}");
        let pred = net.predict(&[0.4, 0.2])[0];
        assert!((pred - (0.5 * 0.4 - 0.3 * 0.2)).abs() < 0.05, "pred={pred}");
    }

    #[test]
    fn fits_xor_like_nonlinearity() {
        let mut rng = Rng::new(3);
        let mut net = Mlp::new(&[2, 12, 1], &mut rng);
        let xs = vec![
            vec![0.0, 0.0],
            vec![0.0, 1.0],
            vec![1.0, 0.0],
            vec![1.0, 1.0],
        ];
        let ys = vec![vec![0.0], vec![1.0], vec![1.0], vec![0.0]];
        let loss = net.fit(&xs, &ys, 3_000, 0.1, &mut rng);
        assert!(loss < 0.01, "loss={loss}");
        assert!(net.predict(&[1.0, 0.0])[0] > 0.8);
        assert!(net.predict(&[1.0, 1.0])[0] < 0.2);
    }

    #[test]
    fn multi_output_regression() {
        let mut rng = Rng::new(5);
        let mut net = Mlp::new(&[1, 10, 2], &mut rng);
        let xs: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64 / 50.0 - 1.0]).collect();
        let ys: Vec<Vec<f64>> = xs.iter().map(|x| vec![x[0].abs(), -x[0]]).collect();
        let loss = net.fit(&xs, &ys, 800, 0.05, &mut rng);
        assert!(loss < 5e-3, "loss={loss}");
    }

    #[test]
    fn training_reduces_loss() {
        let mut rng = Rng::new(7);
        let mut net = Mlp::new(&[3, 6, 1], &mut rng);
        let xs: Vec<Vec<f64>> = (0..50)
            .map(|_| (0..3).map(|_| rng.uniform(-1.0, 1.0)).collect())
            .collect();
        let ys: Vec<Vec<f64>> = xs.iter().map(|x| vec![x.iter().sum::<f64>()]).collect();
        let first = net.fit(&xs, &ys, 1, 0.02, &mut rng);
        let later = net.fit(&xs, &ys, 100, 0.02, &mut rng);
        assert!(later < first, "{later} !< {first}");
    }
}
