//! SP — the Static-ANN baseline of [22] (Nine et al., NDM'15).
//!
//! A small neural network is trained *offline* on the historical logs
//! to map transfer context (RTT, bandwidth, file size, file count) to
//! good protocol parameters; at transfer time the prediction is made
//! once and never revisited (the "Static ANN (SP)" of §5).
//!
//! Training targets: for each context group in the corpus, the
//! parameters of the empirically-best log entry (what the original
//! paper's hysteresis mining distils to).

use crate::baselines::api::Optimizer;
use crate::baselines::mlp::Mlp;
use crate::logs::schema::LogEntry;
use crate::offline::features::{raw_features, FeatureScaler};
use crate::util::rng::Rng;
use crate::Params;
use std::collections::BTreeMap;

/// Trained static-ANN model (shared by every SP transfer).
#[derive(Debug, Clone)]
pub struct StaticAnnModel {
    scaler: FeatureScaler,
    net: Mlp,
    max_param: u32,
}

/// Group key: coarse context bucket (network is implied by rtt/bw).
fn group_key(e: &LogEntry) -> (u64, u64, u64) {
    (
        (e.rtt_s * 1e4) as u64,
        e.bandwidth_mbps as u64,
        e.avg_file_mb.log2().floor().max(0.0) as u64,
    )
}

impl StaticAnnModel {
    /// Train on a log corpus.
    pub fn train(entries: &[LogEntry], max_param: u32, seed: u64) -> StaticAnnModel {
        assert!(!entries.is_empty());
        let refs: Vec<&LogEntry> = entries.iter().collect();
        let scaler = FeatureScaler::fit(&refs);

        // best observed params per context group
        let mut best: BTreeMap<(u64, u64, u64), (&LogEntry, f64)> = BTreeMap::new();
        for e in entries {
            let k = group_key(e);
            match best.get(&k) {
                Some((_, th)) if *th >= e.throughput_mbps => {}
                _ => {
                    best.insert(k, (e, e.throughput_mbps));
                }
            }
        }

        let cap = max_param as f64;
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for (e, _) in best.values() {
            xs.push(scaler.apply(raw_features(e)).to_vec());
            ys.push(vec![
                e.params.cc as f64 / cap,
                e.params.p as f64 / cap,
                e.params.pp as f64 / cap,
            ]);
        }
        let mut rng = Rng::new(seed ^ 0x5aa0);
        let mut net = Mlp::new(&[4, 16, 8, 3], &mut rng);
        net.fit(&xs, &ys, 300, 0.02, &mut rng);
        StaticAnnModel {
            scaler,
            net,
            max_param,
        }
    }

    /// Predict parameters for a transfer context.
    pub fn predict(
        &self,
        rtt_s: f64,
        bandwidth_mbps: f64,
        avg_file_mb: f64,
        n_files: u64,
    ) -> Params {
        let f = self
            .scaler
            .transform_query(rtt_s, bandwidth_mbps, avg_file_mb, n_files);
        let out = self.net.predict(&f);
        let cap = self.max_param as f64;
        let clamp = |v: f64| (v * cap).round().clamp(1.0, cap) as u32;
        Params::new(clamp(out[0]), clamp(out[1]), clamp(out[2]))
    }
}

/// Per-transfer SP optimizer: one static prediction.
#[derive(Debug, Clone)]
pub struct StaticAnn {
    params: Params,
}

impl StaticAnn {
    pub fn for_transfer(
        model: &StaticAnnModel,
        rtt_s: f64,
        bandwidth_mbps: f64,
        avg_file_mb: f64,
        n_files: u64,
    ) -> StaticAnn {
        StaticAnn {
            params: model.predict(rtt_s, bandwidth_mbps, avg_file_mb, n_files),
        }
    }
}

impl Optimizer for StaticAnn {
    fn name(&self) -> &'static str {
        "SP"
    }

    fn next_params(&mut self, _last_th: Option<f64>) -> Params {
        self.params
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logs::generator::{generate_history, GeneratorConfig};
    use crate::sim::profile::NetProfile;

    fn corpus() -> &'static Vec<LogEntry> {
        use std::sync::OnceLock;
        static CORPUS: OnceLock<Vec<LogEntry>> = OnceLock::new();
        CORPUS.get_or_init(|| {
            let cfg = GeneratorConfig {
                days: 10.0,
                transfers_per_hour: 10.0,
                seed: 5,
            };
            let mut logs = generate_history(&NetProfile::xsede(), &cfg);
            logs.extend(generate_history(&NetProfile::didclab(), &cfg));
            logs
        })
    }

    #[test]
    fn predictions_in_bounds() {
        let model = StaticAnnModel::train(corpus(), 32, 1);
        for (rtt, bw, f, n) in [
            (0.040, 10_000.0, 1.0, 10_000u64),
            (0.0002, 1_000.0, 2_048.0, 16),
            (0.030, 1_000.0, 64.0, 200),
        ] {
            let q = model.predict(rtt, bw, f, n);
            assert!((1..=32).contains(&q.cc), "{q}");
            assert!((1..=32).contains(&q.p));
            assert!((1..=32).contains(&q.pp));
        }
    }

    #[test]
    fn beats_default_params_in_expectation() {
        // the ANN should recommend more streams than (1,1,1) for a
        // long-RTT 10G path with many large files
        let model = StaticAnnModel::train(corpus(), 32, 2);
        let q = model.predict(0.040, 10_000.0, 1_024.0, 64);
        assert!(q.total_streams() > 2, "{q}");
    }

    #[test]
    fn optimizer_is_static() {
        let model = StaticAnnModel::train(corpus(), 32, 3);
        let mut sp = StaticAnn::for_transfer(&model, 0.04, 10_000.0, 100.0, 100);
        let a = sp.next_params(None);
        assert_eq!(a, sp.next_params(Some(1.0)));
    }

    #[test]
    fn deterministic_training() {
        let c: &Vec<LogEntry> = corpus();
        let m1 = StaticAnnModel::train(c, 32, 9);
        let m2 = StaticAnnModel::train(c, 32, 9);
        assert_eq!(
            m1.predict(0.04, 1e4, 10.0, 100),
            m2.predict(0.04, 1e4, 10.0, 100)
        );
    }
}
