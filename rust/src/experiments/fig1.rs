//! Figure 1: piecewise cubic interpolation surface construction —
//! build one cluster's surfaces from the shared corpus and report
//! their structure (knots, patches, maxima, confidence width), plus a
//! coarse ASCII rendering of the lightest-load surface.

use crate::experiments::common::ctx;
use crate::sim::profile::NetProfile;
use crate::util::table::Table;

pub struct Fig1Result {
    pub n_surfaces: usize,
    pub table: Table,
}

pub fn run() -> Fig1Result {
    let c = ctx();
    let p = NetProfile::xsede();
    let set = c
        .kb
        .query(p.rtt_s, p.bandwidth_mbps, 512.0, 64)
        .expect("kb built");

    let mut t = Table::new(&[
        "bucket",
        "load",
        "pp",
        "patches",
        "coverage",
        "opt-params",
        "opt-th(Mbps)",
        "sigma",
    ]);
    let mut n = 0;
    for b in &set.buckets {
        for s in &b.slices {
            n += 1;
            t.row(&[
                b.bucket.to_string(),
                format!("{:.2}", b.load_intensity),
                s.pp.to_string(),
                format!(
                    "{}x{}",
                    s.fitted.surface.coeffs.len(),
                    s.fitted.surface.coeffs[0].len()
                ),
                format!("{:.0}%", s.coverage * 100.0),
                s.optimal_params.to_string(),
                format!("{:.0}", s.optimal_th),
                format!("{:.1}", s.confidence.sigma),
            ]);
        }
    }
    println!("Figure 1 — constructed piecewise bicubic surfaces (XSEDE cluster)");
    t.print();

    // ASCII heat sketch of the lightest bucket's best slice
    if let Some(b) = set.buckets.first() {
        if let Some(s) = b
            .slices
            .iter()
            .max_by(|a, c| a.optimal_th.total_cmp(&c.optimal_th))
        {
            let dense = s.fitted.surface.dense_eval(2);
            let max = dense
                .iter()
                .flatten()
                .fold(f64::NEG_INFINITY, |a, &b| a.max(b));
            println!(
                "surface sketch (pp={}, rows = p, cols = cc, #=near max):",
                s.pp
            );
            for row in dense.iter().step_by(2) {
                let line: String = row
                    .iter()
                    .step_by(2)
                    .map(|&v| {
                        let r = v / max;
                        if r > 0.9 {
                            '#'
                        } else if r > 0.7 {
                            '+'
                        } else if r > 0.4 {
                            '.'
                        } else {
                            ' '
                        }
                    })
                    .collect();
                println!("  |{line}|");
            }
        }
    }
    Fig1Result {
        n_surfaces: n,
        table: t,
    }
}
