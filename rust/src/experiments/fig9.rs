//! Figures 2/9/10 + §5.4: the multi-user fairness experiment on the
//! Chameleon profile — four users simultaneously running the same
//! optimization technique on one bottleneck.
//!
//! Paper headlines to reproduce in shape: ASM ≈ 1.7× HARP, ≈ 3.4× GO,
//! ≈ 5× No-Optimization in aggregate; ASM's per-user σ roughly half of
//! HARP's; GO/NoOpt fair but slow.

use crate::baselines::api::{OptimizerKind, PolicyAdapter};
use crate::baselines::globus::Globus;
use crate::baselines::harp::Harp;
use crate::experiments::common::ctx;
use crate::online::controller::DynamicTuner;
use crate::sim::dataset::Dataset;
use crate::sim::multiuser::{MultiUserSim, UserPolicy};
use crate::sim::profile::NetProfile;
use crate::util::stats;
use crate::util::table::Table;
use crate::Params;

pub struct Fig9Row {
    pub model: OptimizerKind,
    pub per_user_mbps: Vec<f64>,
    pub aggregate_mbps: f64,
    pub stddev_mbps: f64,
    pub jain: f64,
}

pub struct Fig9Result {
    pub rows: Vec<Fig9Row>,
}

impl Fig9Result {
    pub fn aggregate(&self, model: OptimizerKind) -> f64 {
        self.rows
            .iter()
            .find(|r| r.model == model)
            .map(|r| r.aggregate_mbps)
            .unwrap_or(0.0)
    }

    pub fn stddev(&self, model: OptimizerKind) -> f64 {
        self.rows
            .iter()
            .find(|r| r.model == model)
            .map(|r| r.stddev_mbps)
            .unwrap_or(0.0)
    }
}

const USERS: usize = 4;
const DURATION_S: f64 = 600.0;

/// Policies for one model, or None for models fig9 does not evaluate
/// (the per-chunk optimizers have no multi-user policy form here).
fn policies_for(model: OptimizerKind, dataset: &Dataset) -> Option<Vec<Box<dyn UserPolicy>>> {
    let c = ctx();
    let profile = NetProfile::chameleon();
    (0..USERS)
        .map(|_u| -> Option<Box<dyn UserPolicy>> {
            match model {
                OptimizerKind::Asm => {
                    let set = c
                        .kb
                        .query(
                            profile.rtt_s,
                            profile.bandwidth_mbps,
                            dataset.avg_file_mb,
                            dataset.n_files,
                        )
                        .expect("kb has surfaces")
                        .clone();
                    Some(Box::new(DynamicTuner::with_defaults(set)))
                }
                OptimizerKind::Harp => {
                    Some(Box::new(PolicyAdapter(Harp::plan(&profile, dataset))))
                }
                OptimizerKind::Globus => {
                    Some(Box::new(PolicyAdapter(Globus::for_dataset(dataset))))
                }
                OptimizerKind::NoOpt => Some(Box::new(move |_: &_| Params::DEFAULT)),
                _ => None,
            }
        })
        .collect()
}

pub fn run() -> Fig9Result {
    let dataset = Dataset::new(512, 256.0);
    let models = [
        OptimizerKind::Asm,
        OptimizerKind::Harp,
        OptimizerKind::Globus,
        OptimizerKind::NoOpt,
    ];

    let mut rows = Vec::new();
    for model in models {
        let mut sim = MultiUserSim::new(NetProfile::chameleon(), 0x519);
        let Some(mut pols) = policies_for(model, &dataset) else {
            eprintln!(
                "fig9: skipping {} — no multi-user policy form for this model",
                model.label()
            );
            continue;
        };
        let ds = vec![dataset.clone(); USERS];
        let out = sim.run(&mut pols, &ds, DURATION_S);
        let per_user: Vec<f64> = out.iter().map(|u| u.mean_throughput_mbps).collect();
        rows.push(Fig9Row {
            model,
            aggregate_mbps: per_user.iter().sum(),
            stddev_mbps: stats::std_pop(&per_user),
            jain: stats::jain_index(&per_user),
            per_user_mbps: per_user,
        });
    }

    let mut t = Table::new(&[
        "model", "user1", "user2", "user3", "user4", "aggregate", "stddev", "jain",
    ]);
    for r in &rows {
        let mut row: Vec<String> = vec![r.model.label().to_string()];
        row.extend(r.per_user_mbps.iter().map(|v| format!("{v:.0}")));
        row.push(format!("{:.0}", r.aggregate_mbps));
        row.push(format!("{:.1}", r.stddev_mbps));
        row.push(format!("{:.3}", r.jain));
        t.row(&row);
    }
    println!(
        "Figures 2/9/10 — {USERS}-user contention on Chameleon ({DURATION_S:.0}s, Mbps)"
    );
    t.print();

    let res = Fig9Result { rows };
    let asm = res.aggregate(OptimizerKind::Asm);
    println!(
        "  ASM vs HARP: {:.2}x (paper 1.7x) | vs GO: {:.2}x (paper 3.4x) | vs NoOpt: {:.2}x (paper 5x)",
        asm / res.aggregate(OptimizerKind::Harp).max(1e-9),
        asm / res.aggregate(OptimizerKind::Globus).max(1e-9),
        asm / res.aggregate(OptimizerKind::NoOpt).max(1e-9),
    );
    println!(
        "  per-user stddev: ASM {:.1} vs HARP {:.1} (paper: 54.98 vs 115.49)",
        res.stddev(OptimizerKind::Asm),
        res.stddev(OptimizerKind::Harp)
    );
    res
}
