//! Figures 2/9/10 + §5.4: the multi-user fairness experiment on the
//! Chameleon profile — users simultaneously running the same
//! optimization technique on one bottleneck, swept over user counts.
//!
//! Paper headlines to reproduce in shape (at the paper's four users):
//! ASM ≈ 1.7× HARP, ≈ 3.4× GO, ≈ 5× No-Optimization in aggregate;
//! ASM's per-user σ roughly half of HARP's; GO/NoOpt fair but slow.
//!
//! The `(model, user-count)` grid fans out over [`crate::util::par`]
//! via [`par_cells`]: each cell's `MultiUserSim` event loop stays
//! serial inside the cell, the cell seed is [`Rng::fork`]`(FIG9_SEED,
//! cell_idx)` (a pure function of the index, never of execution
//! order), and results reduce in cell order — so the full result is
//! bit-identical for any `PALLAS_THREADS` setting
//! (`tests/prop_fig9_parallel.rs` proves 1/2/8).  Cells whose model
//! has no multi-user policy form are skipped with a warning *and* an
//! `experiment.skip` trace event, so skips show up in JSONL exports
//! instead of vanishing into stderr.

use std::sync::Arc;

use crate::baselines::api::{OptimizerKind, PolicyAdapter};
use crate::baselines::globus::Globus;
use crate::baselines::harp::Harp;
use crate::experiments::common::{ctx, par_cells};
use crate::online::controller::DynamicTuner;
use crate::sim::dataset::Dataset;
use crate::sim::multiuser::{outcomes_digest, MultiUserSim, UserPolicy};
use crate::sim::profile::NetProfile;
use crate::util::json::Value;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::util::table::Table;
use crate::util::trace::Tracer;
use crate::Params;

/// Seed quoted in EXPERIMENTS.md; parent of every cell fork.
pub const FIG9_SEED: u64 = 0x519;
/// Contention levels swept; [`USERS_PAPER`] is the paper's headline.
pub const USER_COUNTS: [usize; 4] = [1, 2, 4, 8];
pub const USERS_PAPER: usize = 4;
const DURATION_S: f64 = 600.0;
/// Scope-id namespace for per-cell skip events (offset by cell index).
const TRACE_SCOPE_BASE: u64 = 0xF19_0000;

pub struct Fig9Row {
    pub model: OptimizerKind,
    pub users: usize,
    pub per_user_mbps: Vec<f64>,
    pub aggregate_mbps: f64,
    pub stddev_mbps: f64,
    pub jain: f64,
    /// [`outcomes_digest`] of the cell's full simulation output.
    pub digest: u64,
}

/// A grid cell fig9 could not evaluate (no multi-user policy form).
pub struct Fig9Skip {
    pub model: OptimizerKind,
    pub users: usize,
    pub reason: &'static str,
}

pub struct Fig9Result {
    pub rows: Vec<Fig9Row>,
    pub skipped: Vec<Fig9Skip>,
}

impl Fig9Result {
    /// The row for one grid cell, if it was evaluated.
    pub fn row(&self, model: OptimizerKind, users: usize) -> Option<&Fig9Row> {
        self.rows
            .iter()
            .find(|r| r.model == model && r.users == users)
    }

    /// Aggregate Mbps at the paper's user count.
    pub fn aggregate(&self, model: OptimizerKind) -> f64 {
        self.row(model, USERS_PAPER)
            .map(|r| r.aggregate_mbps)
            .unwrap_or(0.0)
    }

    /// Per-user stddev at the paper's user count.
    pub fn stddev(&self, model: OptimizerKind) -> f64 {
        self.row(model, USERS_PAPER)
            .map(|r| r.stddev_mbps)
            .unwrap_or(0.0)
    }

    /// FNV-1a over every row's and skip's exact content — the witness
    /// `tests/prop_fig9_parallel.rs` compares across thread counts.
    pub fn digest(&self) -> u64 {
        fn mix(h: &mut u64, x: u64) {
            for byte in x.to_le_bytes() {
                *h ^= byte as u64;
                *h = h.wrapping_mul(0x0000_0100_0000_01B3);
            }
        }
        fn mix_str(h: &mut u64, s: &str) {
            mix(h, s.len() as u64);
            for &b in s.as_bytes() {
                mix(h, b as u64);
            }
        }
        let mut h = 0xCBF2_9CE4_8422_2325u64;
        mix(&mut h, self.rows.len() as u64);
        for r in &self.rows {
            mix_str(&mut h, r.model.label());
            mix(&mut h, r.users as u64);
            for &v in &r.per_user_mbps {
                mix(&mut h, v.to_bits());
            }
            mix(&mut h, r.aggregate_mbps.to_bits());
            mix(&mut h, r.stddev_mbps.to_bits());
            mix(&mut h, r.jain.to_bits());
            mix(&mut h, r.digest);
        }
        mix(&mut h, self.skipped.len() as u64);
        for s in &self.skipped {
            mix_str(&mut h, s.model.label());
            mix(&mut h, s.users as u64);
            mix_str(&mut h, s.reason);
        }
        h
    }
}

/// Policies for one model at one user count, or the skip reason for
/// models fig9 does not evaluate.
fn policies_for(
    model: OptimizerKind,
    users: usize,
    dataset: &Dataset,
) -> Result<Vec<Box<dyn UserPolicy>>, &'static str> {
    let profile = NetProfile::chameleon();
    (0..users)
        .map(|_u| -> Result<Box<dyn UserPolicy>, &'static str> {
            match model {
                OptimizerKind::Asm => {
                    let set = ctx()
                        .kb
                        .query(
                            profile.rtt_s,
                            profile.bandwidth_mbps,
                            dataset.avg_file_mb,
                            dataset.n_files,
                        )
                        .ok_or("knowledge base has no surface for this profile/dataset")?
                        .clone();
                    Ok(Box::new(DynamicTuner::with_defaults(set)))
                }
                OptimizerKind::Harp => {
                    Ok(Box::new(PolicyAdapter(Harp::plan(&profile, dataset))))
                }
                OptimizerKind::Globus => {
                    Ok(Box::new(PolicyAdapter(Globus::for_dataset(dataset))))
                }
                OptimizerKind::NoOpt => Ok(Box::new(move |_: &_| Params::DEFAULT)),
                _ => Err("no multi-user policy form for this model"),
            }
        })
        .collect()
}

/// One evaluated or skipped grid cell (the fan-out's unit result).
enum CellOut {
    Row(Fig9Row),
    Skip(Fig9Skip),
}

pub fn run() -> Fig9Result {
    run_traced(None)
}

/// The full experiment (paper model set), optionally traced.
pub fn run_traced(tracer: Option<&Arc<Tracer>>) -> Fig9Result {
    run_models_traced(
        &[
            OptimizerKind::Asm,
            OptimizerKind::Harp,
            OptimizerKind::Globus,
            OptimizerKind::NoOpt,
        ],
        tracer,
    )
}

/// Run the `(model, user-count)` grid for an explicit model set.
pub fn run_models_traced(
    models: &[OptimizerKind],
    tracer: Option<&Arc<Tracer>>,
) -> Fig9Result {
    let dataset = Dataset::new(512, 256.0);
    // The shared context builds its own parallel pipeline; touch it
    // before the fan-out so the build never happens inside a pool
    // worker (where nested par_map degrades to serial).
    if models.contains(&OptimizerKind::Asm) {
        let _ = ctx();
    }

    let units: Vec<(OptimizerKind, usize)> = models
        .iter()
        .flat_map(|&m| USER_COUNTS.iter().map(move |&u| (m, u)))
        .collect();

    let cells = par_cells(&units, |ci, &(model, users)| {
        match policies_for(model, users, &dataset) {
            Err(reason) => CellOut::Skip(Fig9Skip {
                model,
                users,
                reason,
            }),
            Ok(mut pols) => {
                // serial-identical cell seed: pure in the cell index
                let seed = Rng::fork(FIG9_SEED, ci as u64).next_u64();
                let mut sim = MultiUserSim::new(NetProfile::chameleon(), seed);
                let ds = vec![dataset.clone(); users];
                let out = sim.run(&mut pols, &ds, DURATION_S);
                let per_user: Vec<f64> =
                    out.iter().map(|u| u.mean_throughput_mbps).collect();
                CellOut::Row(Fig9Row {
                    model,
                    users,
                    aggregate_mbps: per_user.iter().sum(),
                    stddev_mbps: stats::std_pop(&per_user),
                    jain: stats::jain_index(&per_user),
                    digest: outcomes_digest(&out),
                    per_user_mbps: per_user,
                })
            }
        }
    });

    let mut rows = Vec::new();
    let mut skipped = Vec::new();
    for (ci, cell) in cells.into_iter().enumerate() {
        match cell {
            CellOut::Row(r) => rows.push(r),
            CellOut::Skip(s) => {
                eprintln!(
                    "fig9: skipping {} at {} users — {}",
                    s.model.label(),
                    s.users,
                    s.reason
                );
                // skips must show in JSONL exports, not just stderr
                let mut scope = Tracer::scope_opt(tracer, TRACE_SCOPE_BASE + ci as u64);
                scope.event(
                    "experiment.skip",
                    0.0,
                    vec![
                        ("experiment", Value::str("fig9")),
                        ("model", Value::str(s.model.label())),
                        ("users", Value::Num(s.users as f64)),
                        ("reason", Value::str(s.reason)),
                    ],
                );
                scope.count("fig9.skips", 1);
                skipped.push(s);
            }
        }
    }
    let res = Fig9Result { rows, skipped };

    let mut t = Table::new(&[
        "model", "user1", "user2", "user3", "user4", "aggregate", "stddev", "jain",
    ]);
    for r in res.rows.iter().filter(|r| r.users == USERS_PAPER) {
        let mut row: Vec<String> = vec![r.model.label().to_string()];
        row.extend(r.per_user_mbps.iter().map(|v| format!("{v:.0}")));
        row.push(format!("{:.0}", r.aggregate_mbps));
        row.push(format!("{:.1}", r.stddev_mbps));
        row.push(format!("{:.3}", r.jain));
        t.row(&row);
    }
    println!(
        "Figures 2/9/10 — {USERS_PAPER}-user contention on Chameleon ({DURATION_S:.0}s, Mbps)"
    );
    t.print();

    let mut sweep = Table::new(&["model", "u=1", "u=2", "u=4", "u=8"]);
    for &m in models {
        if !res.rows.iter().any(|r| r.model == m) {
            continue;
        }
        let mut row = vec![m.label().to_string()];
        for &u in &USER_COUNTS {
            row.push(match res.row(m, u) {
                Some(r) => format!("{:.0}", r.aggregate_mbps),
                None => "-".to_string(),
            });
        }
        sweep.row(&row);
    }
    println!("  aggregate Mbps by user count:");
    sweep.print();

    let asm = res.aggregate(OptimizerKind::Asm);
    println!(
        "  ASM vs HARP: {:.2}x (paper 1.7x) | vs GO: {:.2}x (paper 3.4x) | vs NoOpt: {:.2}x (paper 5x)",
        asm / res.aggregate(OptimizerKind::Harp).max(1e-9),
        asm / res.aggregate(OptimizerKind::Globus).max(1e-9),
        asm / res.aggregate(OptimizerKind::NoOpt).max(1e-9),
    );
    println!(
        "  per-user stddev: ASM {:.1} vs HARP {:.1} (paper: 54.98 vs 115.49)",
        res.stddev(OptimizerKind::Asm),
        res.stddev(OptimizerKind::Harp)
    );
    res
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn skip_emits_trace_event() {
        // NelderMead has no multi-user policy form, so every cell
        // skips — and every skip must land in the JSONL export.
        // (Does not touch ctx(): the skip path needs no knowledge base.)
        let tracer = Arc::new(Tracer::new());
        let res = run_models_traced(&[OptimizerKind::NelderMead], Some(&tracer));
        assert!(res.rows.is_empty());
        assert_eq!(res.skipped.len(), USER_COUNTS.len());
        let text = tracer.export_string();
        assert!(text.contains("\"name\":\"experiment.skip\""));
        assert!(text.contains("\"experiment\":\"fig9\""));
        assert!(text.contains("\"reason\":\"no multi-user policy form for this model\""));
        assert_eq!(
            tracer.metrics().counter("fig9.skips"),
            USER_COUNTS.len() as u64
        );
    }

    #[test]
    fn untraced_skip_is_still_counted_in_result() {
        let res = run_models_traced(&[OptimizerKind::SingleChunk], None);
        assert!(res.rows.is_empty());
        assert_eq!(res.skipped.len(), USER_COUNTS.len());
        for s in &res.skipped {
            assert_eq!(s.model, OptimizerKind::SingleChunk);
        }
    }
}
