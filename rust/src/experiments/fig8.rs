//! Figure 8: prediction accuracy vs number of sample transfers for the
//! models that sample online (ASM, HARP, ANN+OT).  The paper: ASM hits
//! ~93% within 3 samples and saturates; HARP reaches ~85% with 3;
//! ANN+OT ~87.3%.
//!
//! After each model consumes k sample transfers, we measure the Eq-21
//! agreement between its predicted throughput and the throughput a
//! validation chunk actually achieves at its chosen parameters.

use crate::baselines::api::{AsmOptimizer, OptimizerKind};
use crate::coordinator::metrics::accuracy_pct;
use crate::experiments::common::{ctx, par_cells, request, OFFPEAK_PHASE_S, PEAK_PHASE_S};
use crate::sim::dataset::FileSizeClass;
use crate::sim::engine::SimEnv;
use crate::sim::profile::NetProfile;
use crate::util::stats;
use crate::util::table::Table;

pub struct Fig8Result {
    /// model -> accuracy per k (1..=MAX_K)
    pub curves: Vec<(OptimizerKind, Vec<f64>)>,
}

const MAX_K: usize = 5;

fn accuracy_curve(model: OptimizerKind) -> Vec<f64> {
    let c = ctx();
    let base = 7000 + model.label().len() as u64 * 100;
    let mut units = Vec::new();
    for class in FileSizeClass::all() {
        for peak in [false, true] {
            for rep in 0..2 {
                units.push((class, peak, rep));
            }
        }
    }
    // each (class, peak, rep) cell owns its SimEnv and optimizer, so
    // the fan-out is independent; ids replay the serial sequence
    // (base + 1, base + 2, …) and the per-k merge runs in cell order
    let per_cell = par_cells(&units, |ci, &(class, peak, rep)| {
        let id = base + ci as u64 + 1;
        let profile = NetProfile::xsede();
        let req = request(id, &profile, class, model, peak, rep);
        let mut env = SimEnv::new(req.profile.clone(), req.seed).with_phase(if peak {
            PEAK_PHASE_S
        } else {
            OFFPEAK_PHASE_S
        });
        let mut opt = c.orchestrator.build_optimizer(&req);
        let mut last = None;
        let mut prev = None;
        let mut cell_k: Vec<Vec<f64>> = vec![Vec::new(); MAX_K];
        for k in 0..MAX_K {
            // one sample transfer
            let params = opt.next_params(last);
            let chunk = req.dataset.sample_chunk(0.01);
            let (th, _) = env.transfer_chunk(params, &chunk, prev);
            last = Some(th);
            prev = Some(params);
            // validation: penalty-free steady measurement at the
            // model's current operating point vs its prediction
            if let Some(pred) = opt.predicted_th() {
                let probe_params = opt.next_params(last);
                let load = env.load_now();
                let achieved = env
                    .model
                    .sample(probe_params, &req.dataset, &load, &mut env.rng);
                cell_k[k].push(accuracy_pct(achieved, pred));
                // keep the optimizer's state machine consistent:
                // the probe result is also its next feedback
                last = Some(achieved);
                prev = Some(probe_params);
            }
        }
        cell_k
    });
    let mut per_k: Vec<Vec<f64>> = vec![Vec::new(); MAX_K];
    for cell in per_cell {
        for (k, vs) in cell.into_iter().enumerate() {
            per_k[k].extend(vs);
        }
    }
    per_k.into_iter().map(|v| stats::mean(&v)).collect()
}

pub fn run() -> Fig8Result {
    // make sure ASM's tuner type is linked in even if unused elsewhere
    let _ = std::any::type_name::<AsmOptimizer>();
    let models = [
        OptimizerKind::Asm,
        OptimizerKind::Harp,
        OptimizerKind::AnnOt,
    ];
    let curves: Vec<(OptimizerKind, Vec<f64>)> = models
        .iter()
        .map(|&m| (m, accuracy_curve(m)))
        .collect();

    let fmt = |v: f64| {
        if v <= 0.0 {
            "- (probing)".to_string()
        } else {
            format!("{v:.1}%")
        }
    };
    let mut t = Table::new(&["samples", "ASM", "HARP", "ANN+OT"]);
    for k in 0..MAX_K {
        t.row(&[
            (k + 1).to_string(),
            fmt(curves[0].1[k]),
            fmt(curves[1].1[k]),
            fmt(curves[2].1[k]),
        ]);
    }
    println!("Figure 8 — prediction accuracy vs sample transfers (XSEDE)");
    t.print();
    println!("  paper: ASM ~93% @3 samples; HARP ~85%; ANN+OT ~87.3%");

    Fig8Result { curves }
}
