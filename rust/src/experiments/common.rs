//! Shared experiment context: one synthetic six-week log corpus, one
//! knowledge base, one trained model per ANN baseline — built once per
//! process (the benches all reuse it) with every seed fixed so runs
//! reproduce bit-for-bit.

use crate::baselines::ann_ot::AnnOtModel;
use crate::baselines::static_ann::StaticAnnModel;
use crate::coordinator::orchestrator::{Orchestrator, OrchestratorConfig, TransferRequest};
use crate::baselines::api::OptimizerKind;
use crate::logs::generator::{generate_history, GeneratorConfig};
use crate::logs::schema::LogEntry;
use crate::offline::pipeline::{KnowledgeBase, OfflineConfig};
use crate::sim::dataset::{Dataset, FileSizeClass};
use crate::sim::profile::NetProfile;
use crate::util::rng::Rng;
use std::sync::{Arc, OnceLock};

/// Seconds of diurnal phase for peak (14:00) and off-peak (03:00).
pub const PEAK_PHASE_S: f64 = 14.0 * 3600.0;
pub const OFFPEAK_PHASE_S: f64 = 3.0 * 3600.0;

/// History length (days).  The paper used six weeks; 14 days gives the
/// same surface coverage from this generator at a single-core-friendly
/// build cost (`TWOPHASE_DAYS` overrides).
pub fn history_days() -> f64 {
    std::env::var("TWOPHASE_DAYS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(14.0)
}

/// Shared context for all experiments.
pub struct ExperimentContext {
    pub logs: Vec<LogEntry>,
    pub kb: Arc<KnowledgeBase>,
    pub sp_model: Arc<StaticAnnModel>,
    pub annot_model: Arc<AnnOtModel>,
    pub orchestrator: Orchestrator,
}

impl ExperimentContext {
    fn build() -> ExperimentContext {
        let days = history_days();
        let mut logs = Vec::new();
        for profile in NetProfile::all() {
            logs.extend(generate_history(
                &profile,
                &GeneratorConfig {
                    days,
                    transfers_per_hour: 8.0,
                    seed: 0xB16_DA7A,
                },
            ));
        }
        let kb = Arc::new(KnowledgeBase::build_native(
            logs.clone(),
            OfflineConfig::default(),
        ));
        let sp_model = Arc::new(StaticAnnModel::train(&logs, 32, 0xE1));
        let annot_model = Arc::new(AnnOtModel::train(&logs, 32, 0xE2));
        let orchestrator = Orchestrator::new(
            Arc::clone(&kb),
            Arc::clone(&sp_model),
            Arc::clone(&annot_model),
            OrchestratorConfig::default(),
        )
        // pallas-lint: allow(panic-in-lib, process-wide experiment-harness init; an empty knowledge base from the fixed-seed corpus is unrecoverable and must abort loudly)
        .expect("experiment corpus yields a non-empty knowledge base");
        ExperimentContext {
            logs,
            kb,
            sp_model,
            annot_model,
            orchestrator,
        }
    }
}

/// The process-wide context (built on first use).
pub fn ctx() -> &'static ExperimentContext {
    static CTX: OnceLock<ExperimentContext> = OnceLock::new();
    CTX.get_or_init(ExperimentContext::build)
}

/// Fan an experiment's independent cells out over the deterministic
/// thread pool ([`crate::util::par`]).  Every cell's seed and request
/// id must be a pure function of its index — never of execution order
/// — so the output is bit-identical for any `PALLAS_THREADS` setting
/// (threads = 1 recovers the serial loop exactly).  Results come back
/// in cell order.
pub fn par_cells<T, U, F>(cells: &[T], f: F) -> Vec<U>
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T) -> U + Sync,
{
    crate::util::par::par_map(cells, f)
}

/// Repetitions per cell (`TWOPHASE_REPS` overrides; default 3).
pub fn reps() -> usize {
    std::env::var("TWOPHASE_REPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(3)
}

/// A reproducible dataset for (class, repetition).
pub fn dataset_for(class: FileSizeClass, rep: usize) -> Dataset {
    let mut rng = Rng::new(0xDA7A ^ (rep as u64) << 8 ^ class.name().len() as u64);
    Dataset::sample(class, &mut rng)
}

/// Build a transfer request for one experiment cell.
pub fn request(
    id: u64,
    profile: &NetProfile,
    class: FileSizeClass,
    model: OptimizerKind,
    peak: bool,
    rep: usize,
) -> TransferRequest {
    TransferRequest {
        id,
        profile: profile.clone(),
        dataset: dataset_for(class, rep),
        model,
        seed: 0x5EED ^ id,
        phase_s: if peak { PEAK_PHASE_S } else { OFFPEAK_PHASE_S },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_for_is_reproducible_and_classed() {
        for class in FileSizeClass::all() {
            let a = dataset_for(class, 1);
            let b = dataset_for(class, 1);
            assert_eq!(a, b);
            assert_eq!(a.class(), class);
            assert_ne!(a, dataset_for(class, 2));
        }
    }

    #[test]
    fn phases() {
        assert!(PEAK_PHASE_S > OFFPEAK_PHASE_S);
    }
}
