//! Figure 4(b): accuracy of surface-construction methods — quadratic
//! regression vs cubic regression vs piecewise bicubic spline, on a
//! 70/30 train/test split of same-condition observations (the paper
//! finds the spline wins at ~85%).

use crate::logs::generator::PARAM_GRID;
use crate::offline::regression::{Degree, PolySurface};
use crate::offline::surface::{NativeSurfaceBackend, SurfaceBackend, SurfaceGrid};
use crate::sim::dataset::Dataset;
use crate::sim::profile::NetProfile;
use crate::sim::traffic::TrafficProcess;
use crate::sim::transfer::ThroughputModel;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::util::table::Table;
use crate::Params;

pub struct Fig4bResult {
    pub quadratic_acc: f64,
    pub cubic_acc: f64,
    pub spline_acc: f64,
}

/// Mean Eq-21 accuracy over a test set of (params, th).
fn accuracy<F: Fn(Params) -> f64>(test: &[(Params, f64)], predict: F) -> f64 {
    let accs: Vec<f64> = test
        .iter()
        .map(|(q, th)| {
            let pred = predict(*q);
            (100.0 - (pred - th).abs() / th.max(1.0) * 100.0).max(0.0)
        })
        .collect();
    stats::mean(&accs)
}

pub fn run() -> Fig4bResult {
    // observations from one condition (fixed load), replicated with
    // noise over the full parameter grid — the per-(cluster, bucket,
    // pp) slice setting the offline phase fits in
    let p = NetProfile::didclab_xsede();
    let model = ThroughputModel::new(p.clone());
    let load = TrafficProcess::fixed(&p, 0.3);
    let dataset = Dataset::new(256, 128.0);
    let mut rng = Rng::new(0x46b);

    let mut obs: Vec<(Params, f64)> = Vec::new();
    for &pv in &PARAM_GRID {
        for &cc in &PARAM_GRID {
            for _ in 0..4 {
                let q = Params::new(cc, pv, 8);
                obs.push((q, model.sample(q, &dataset, &load, &mut rng)));
            }
        }
    }
    rng.shuffle(&mut obs);
    let split = obs.len() * 7 / 10;
    let (train, test) = obs.split_at(split);

    // regression baselines
    let quad = PolySurface::fit(Degree::Quadratic, train).expect("quadratic fit");
    let cubic = PolySurface::fit(Degree::Cubic, train).expect("cubic fit");

    // piecewise bicubic spline via the shared backend
    let grid = SurfaceGrid::from_observations(train);
    let fit = NativeSurfaceBackend
        .fit_batch(&grid.xs, &grid.ys, &[grid.values.clone()], 8)
        .remove(0);

    let quadratic_acc = accuracy(test, |q| quad.predict(q));
    let cubic_acc = accuracy(test, |q| cubic.predict(q));
    let spline_acc = accuracy(test, |q| fit.surface.eval(q.p as f64, q.cc as f64));

    let mut t = Table::new(&["model", "test accuracy"]);
    t.row(&["quadratic regression".into(), format!("{quadratic_acc:.1}%")]);
    t.row(&["cubic regression".into(), format!("{cubic_acc:.1}%")]);
    t.row(&["piecewise cubic spline".into(), format!("{spline_acc:.1}%")]);
    println!("Figure 4(b) — surface construction accuracy (70/30 split)");
    t.print();
    println!("  paper: spline ≈ 85%, above both regressions");

    Fig4bResult {
        quadratic_acc,
        cubic_acc,
        spline_acc,
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn spline_beats_both_regressions() {
        let r = super::run();
        assert!(
            r.spline_acc > r.quadratic_acc,
            "spline {} vs quadratic {}",
            r.spline_acc,
            r.quadratic_acc
        );
        assert!(
            r.spline_acc > r.cubic_acc,
            "spline {} vs cubic {}",
            r.spline_acc,
            r.cubic_acc
        );
        // paper reports ~85%; we require the same ballpark
        assert!(r.spline_acc > 80.0, "spline accuracy {}", r.spline_acc);
    }
}
