//! Figure 4(a): distribution of throughput values under similar
//! external loads — repeated transfers at one parameter point under a
//! fixed load are approximately Gaussian around the surface value.
//!
//! The sweep fans out per *cell* over [`crate::util::par`]: the single
//! RNG that used to thread through all 600 draws is replaced by
//! [`Rng::fork`]`(FIG4A_SEED, cell_idx)` — a pure function of the cell
//! index — so every cell's draws are independent of execution order and
//! the flattened sample vector is bit-identical for any
//! `PALLAS_THREADS` setting.  Re-seeding moved the realized sample
//! values, so the statistical goldens are re-pinned (with explicit
//! tolerance derivations) in `tests::reseeded_sweep_matches_goldens`.

use crate::sim::dataset::Dataset;
use crate::sim::profile::NetProfile;
use crate::sim::traffic::TrafficProcess;
use crate::sim::transfer::ThroughputModel;
use crate::util::par;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::Params;

/// Seed quoted in EXPERIMENTS.md; parent of every cell fork.
pub const FIG4A_SEED: u64 = 0x46a;
/// Parallel grid cells; each draws [`DRAWS_PER_CELL`] samples on its
/// own forked stream.  40 × 15 keeps the paper-scale 600-draw sweep.
pub const CELLS: usize = 40;
pub const DRAWS_PER_CELL: usize = 15;

pub struct Fig4aResult {
    pub mean: f64,
    pub sigma: f64,
    pub within_1s: f64,
    pub within_2s: f64,
    pub histogram: Vec<usize>,
    /// Mean of each cell's draws, in cell order — the per-cell goldens.
    pub cell_means: Vec<f64>,
    /// Noise-free surface value the samples scatter around.
    pub steady_mbps: f64,
}

pub fn run() -> Fig4aResult {
    let p = NetProfile::xsede();
    let model = ThroughputModel::new(p.clone());
    let load = TrafficProcess::fixed(&p, 0.35);
    let dataset = Dataset::new(128, 256.0);
    let params = Params::new(8, 4, 8);
    let steady_mbps = model.steady(params, &dataset, &load);

    let per_cell: Vec<Vec<f64>> = par::par_indices(CELLS, |ci| {
        let mut rng = Rng::fork(FIG4A_SEED, ci as u64);
        (0..DRAWS_PER_CELL)
            .map(|_| model.sample(params, &dataset, &load, &mut rng))
            .collect()
    });
    let cell_means: Vec<f64> = per_cell.iter().map(|c| stats::mean(c)).collect();
    let samples: Vec<f64> = per_cell.into_iter().flatten().collect();

    let mean = stats::mean(&samples);
    let sigma = stats::std_pop(&samples);
    let within = |k: f64| {
        samples
            .iter()
            .filter(|&&x| (x - mean).abs() <= k * sigma)
            .count() as f64
            / samples.len() as f64
    };
    let (lo, hi) = (mean - 4.0 * sigma, mean + 4.0 * sigma);
    let histogram = stats::histogram(&samples, lo, hi, 17);

    println!("Figure 4(a) — throughput distribution at {params} under fixed load 0.35");
    println!(
        "  mean = {mean:.1} Mbps, sigma = {sigma:.1} Mbps ({CELLS} cells x {DRAWS_PER_CELL} draws)"
    );
    println!(
        "  within 1σ: {:.1}% (Gaussian: 68.3%), within 2σ: {:.1}% (95.4%)",
        within(1.0) * 100.0,
        within(2.0) * 100.0
    );
    let peak = histogram.iter().copied().max().unwrap_or(1).max(1) as f64;
    for (i, &c) in histogram.iter().enumerate() {
        let x = lo + (hi - lo) * (i as f64 + 0.5) / 17.0;
        let bar = "█".repeat((c as f64 / peak * 40.0) as usize);
        println!("  {x:7.0} | {bar} {c}");
    }

    Fig4aResult {
        mean,
        sigma,
        within_1s: within(1.0),
        within_2s: within(2.0),
        histogram,
        cell_means,
        steady_mbps,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn distribution_is_approximately_gaussian() {
        let r = run();
        assert!(r.mean > 0.0 && r.sigma > 0.0);
        // lognormal with sigma=0.05 is near-Gaussian: coverage within a
        // few points of the normal values
        assert!((r.within_1s - 0.683).abs() < 0.06, "1σ = {}", r.within_1s);
        assert!((r.within_2s - 0.954).abs() < 0.04, "2σ = {}", r.within_2s);
        // histogram peaks in the middle
        let peak_bin = r
            .histogram
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .unwrap()
            .0;
        assert!((6..=10).contains(&peak_bin), "peak at bin {peak_bin}");
    }

    #[test]
    fn reseeded_sweep_matches_goldens() {
        // Statistical goldens for the forked-seed sweep, pinned relative
        // to the deterministic steady() value (samples are steady ×
        // lognormal(0, 0.05), so every ratio below is seed-family
        // invariant and drift in the per-cell fork shows up immediately).
        let r = run();
        assert_eq!(r.cell_means.len(), CELLS);
        assert!(r.steady_mbps > 0.0);

        // Grand mean: E[lognormal(0, 0.05)] = exp(0.00125) ≈ 1.00125;
        // SE of the mean over 600 draws ≈ 0.05/√600 ≈ 0.00204.
        // Tolerance 0.012 leaves > 5 SE of headroom past the offset.
        assert!(
            (r.mean / r.steady_mbps - 1.0).abs() < 0.012,
            "mean/steady = {}",
            r.mean / r.steady_mbps
        );

        // Spread: sd of lognormal(0, 0.05) ≈ 0.0501 × steady; the sd
        // estimate over 600 draws has SE ≈ 0.05/√1200 ≈ 0.0014.
        // [0.042, 0.058] is ±5.5 SE around the true value.
        let rel_sigma = r.sigma / r.steady_mbps;
        assert!(
            (0.042..0.058).contains(&rel_sigma),
            "sigma/steady = {rel_sigma}"
        );

        // Per-cell means: SE over 15 draws ≈ 0.05/√15 ≈ 0.0129.
        // Tolerance 0.07 ≈ 5.4 SE; P(any of 40 cells exceeds) ≲ 1e-6.
        for (ci, &cm) in r.cell_means.iter().enumerate() {
            assert!(
                (cm / r.steady_mbps - 1.0).abs() < 0.07,
                "cell {ci}: mean/steady = {}",
                cm / r.steady_mbps
            );
        }
    }

    #[test]
    fn sweep_is_deterministic() {
        let a = run();
        let b = run();
        assert_eq!(a.mean.to_bits(), b.mean.to_bits());
        assert_eq!(a.sigma.to_bits(), b.sigma.to_bits());
        for (x, y) in a.cell_means.iter().zip(&b.cell_means) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}
