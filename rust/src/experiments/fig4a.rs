//! Figure 4(a): distribution of throughput values under similar
//! external loads — repeated transfers at one parameter point under a
//! fixed load are approximately Gaussian around the surface value.

use crate::sim::dataset::Dataset;
use crate::sim::profile::NetProfile;
use crate::sim::traffic::TrafficProcess;
use crate::sim::transfer::ThroughputModel;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::Params;

pub struct Fig4aResult {
    pub mean: f64,
    pub sigma: f64,
    pub within_1s: f64,
    pub within_2s: f64,
    pub histogram: Vec<usize>,
}

pub fn run() -> Fig4aResult {
    let p = NetProfile::xsede();
    let model = ThroughputModel::new(p.clone());
    let load = TrafficProcess::fixed(&p, 0.35);
    let dataset = Dataset::new(128, 256.0);
    let params = Params::new(8, 4, 8);
    let mut rng = Rng::new(0x46a);

    let samples: Vec<f64> = (0..600)
        .map(|_| model.sample(params, &dataset, &load, &mut rng))
        .collect();
    let mean = stats::mean(&samples);
    let sigma = stats::std_pop(&samples);
    let within = |k: f64| {
        samples
            .iter()
            .filter(|&&x| (x - mean).abs() <= k * sigma)
            .count() as f64
            / samples.len() as f64
    };
    let (lo, hi) = (mean - 4.0 * sigma, mean + 4.0 * sigma);
    let histogram = stats::histogram(&samples, lo, hi, 17);

    println!("Figure 4(a) — throughput distribution at {params} under fixed load 0.35");
    println!("  mean = {mean:.1} Mbps, sigma = {sigma:.1} Mbps");
    println!(
        "  within 1σ: {:.1}% (Gaussian: 68.3%), within 2σ: {:.1}% (95.4%)",
        within(1.0) * 100.0,
        within(2.0) * 100.0
    );
    let peak = histogram.iter().copied().max().unwrap_or(1).max(1) as f64;
    for (i, &c) in histogram.iter().enumerate() {
        let x = lo + (hi - lo) * (i as f64 + 0.5) / 17.0;
        let bar = "█".repeat((c as f64 / peak * 40.0) as usize);
        println!("  {x:7.0} | {bar} {c}");
    }

    Fig4aResult {
        mean,
        sigma,
        within_1s: within(1.0),
        within_2s: within(2.0),
        histogram,
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn distribution_is_approximately_gaussian() {
        let r = super::run();
        assert!(r.mean > 0.0 && r.sigma > 0.0);
        // lognormal with sigma=0.05 is near-Gaussian: coverage within a
        // few points of the normal values
        assert!((r.within_1s - 0.683).abs() < 0.06, "1σ = {}", r.within_1s);
        assert!((r.within_2s - 0.954).abs() < 0.04, "2σ = {}", r.within_2s);
        // histogram peaks in the middle
        let peak_bin = r
            .histogram
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .unwrap()
            .0;
        assert!((6..=10).contains(&peak_bin), "peak at bin {peak_bin}");
    }
}
