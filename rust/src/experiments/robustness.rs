//! Robustness experiment: recovered-throughput fraction under
//! escalating fault intensity — the fault-injection capstone.
//!
//! For each model and intensity level we run the same transfer twice
//! with identical seeds: once on a healthy network and once under a
//! deterministic [`FaultPlan`] (link degradation, loss bursts, RTT
//! inflation, traffic surges, endpoint stalls).  The *recovered
//! fraction* is faulted avg throughput / clean avg throughput; a model
//! that detects faults, retries with backoff, and re-tunes its
//! parameters to the degraded network keeps more of its clean
//! throughput than one that holds a static plan.  The paper's
//! two-phase model (ASM) is compared against the static baselines
//! GO, SC, and HARP — the same cast as Fig 5.

use crate::baselines::api::OptimizerKind;
use crate::coordinator::orchestrator::TransferRequest;
use crate::experiments::common::{ctx, par_cells, reps, OFFPEAK_PHASE_S};
use crate::faults::{FaultPlan, FaultPlanConfig};
use crate::sim::dataset::Dataset;
use crate::sim::profile::NetProfile;
use crate::util::table::Table;

/// Fault-intensity sweep (magnitude knob of [`FaultPlanConfig`]).
pub const INTENSITIES: [f64; 3] = [0.3, 0.6, 1.0];

/// Two-phase vs the static baselines.
pub const MODELS: [OptimizerKind; 4] = [
    OptimizerKind::Asm,
    OptimizerKind::Harp,
    OptimizerKind::Globus,
    OptimizerKind::SingleChunk,
];

/// One (model, intensity) cell, averaged over repetitions.
#[derive(Debug, Clone)]
pub struct RobustnessCell {
    pub model: OptimizerKind,
    pub intensity: f64,
    pub clean_mbps: f64,
    pub faulted_mbps: f64,
    /// faulted / clean average throughput
    pub recovered_frac: f64,
    /// mean retried chunk attempts per faulted run
    pub mean_retries: f64,
    /// fraction of faulted runs that moved every byte
    pub completion_rate: f64,
}

pub struct RobustnessResult {
    pub cells: Vec<RobustnessCell>,
}

impl RobustnessResult {
    pub fn frac(&self, model: OptimizerKind, intensity: f64) -> f64 {
        self.cells
            .iter()
            .find(|c| c.model == model && (c.intensity - intensity).abs() < 1e-9)
            .map(|c| c.recovered_frac)
            .unwrap_or(0.0)
    }

    /// Intensity levels at which ASM's recovered fraction strictly
    /// beats every static baseline's.
    pub fn asm_win_levels(&self) -> usize {
        INTENSITIES
            .iter()
            .filter(|&&i| {
                let asm = self.frac(OptimizerKind::Asm, i);
                MODELS[1..].iter().all(|&b| asm > self.frac(b, i))
            })
            .count()
    }
}

/// A fault schedule dense enough that a multi-minute transfer meets
/// several events (the default 6/h barely touches one).
fn fault_cfg(intensity: f64) -> FaultPlanConfig {
    FaultPlanConfig {
        events_per_hour: 60.0,
        ..FaultPlanConfig::with_intensity(intensity)
    }
}

fn request_for(model: OptimizerKind, rep: usize, id: u64) -> TransferRequest {
    TransferRequest {
        id,
        profile: NetProfile::xsede(),
        // 128 GB: a few minutes of clean transfer, so the schedule's
        // events actually land inside the run
        dataset: Dataset::new(256, 512.0),
        model,
        seed: 0x5EED ^ id ^ (rep as u64) << 16,
        phase_s: OFFPEAK_PHASE_S,
    }
}

pub fn run() -> RobustnessResult {
    let orch = &ctx().orchestrator;
    let n_reps = reps();

    // one pool unit per model: seeds and fault schedules are pure
    // functions of (model index, intensity index, rep), so the fan-out
    // reproduces the serial sweep bit-for-bit; flattening in model
    // order restores the serial cell order
    let units: Vec<(usize, OptimizerKind)> = MODELS.iter().copied().enumerate().collect();
    let per_model = par_cells(&units, |_, &(mi, model)| {
        let requests: Vec<TransferRequest> = (0..n_reps)
            .map(|rep| request_for(model, rep, (mi * 100 + rep) as u64))
            .collect();
        let clean: Vec<f64> = requests
            .iter()
            .map(|r| orch.execute(r).avg_throughput_mbps)
            .collect();

        let mut model_cells = Vec::with_capacity(INTENSITIES.len());
        for (ii, &intensity) in INTENSITIES.iter().enumerate() {
            let mut faulted = 0.0;
            let mut retries = 0.0;
            let mut completions = 0usize;
            for (rep, req) in requests.iter().enumerate() {
                // one schedule per (intensity, rep), shared by every
                // model: all models face the same storm
                let plan_seed = 0xFA117 ^ ((ii as u64) << 8) ^ rep as u64;
                let plan =
                    FaultPlan::generate(&req.profile, &fault_cfg(intensity), plan_seed);
                let rr = orch.execute_with_faults(req, Some(plan));
                faulted += rr.report.avg_throughput_mbps;
                retries += rr.retries as f64;
                completions += rr.completed as usize;
            }
            let clean_mean = clean.iter().sum::<f64>() / n_reps as f64;
            let faulted_mean = faulted / n_reps as f64;
            model_cells.push(RobustnessCell {
                model,
                intensity,
                clean_mbps: clean_mean,
                faulted_mbps: faulted_mean,
                recovered_frac: faulted_mean / clean_mean.max(1e-9),
                mean_retries: retries / n_reps as f64,
                completion_rate: completions as f64 / n_reps as f64,
            });
        }
        model_cells
    });
    let cells: Vec<RobustnessCell> = per_model.into_iter().flatten().collect();

    let mut t = Table::new(&[
        "model",
        "intensity",
        "clean Mbps",
        "faulted Mbps",
        "recovered",
        "retries",
        "completed",
    ]);
    for c in &cells {
        t.row(&[
            c.model.label().to_string(),
            format!("{:.1}", c.intensity),
            format!("{:.0}", c.clean_mbps),
            format!("{:.0}", c.faulted_mbps),
            format!("{:.2}", c.recovered_frac),
            format!("{:.1}", c.mean_retries),
            format!("{:.0}%", c.completion_rate * 100.0),
        ]);
    }
    println!(
        "Robustness — recovered throughput fraction under fault injection \
         (XSEDE, {} reps)",
        reps()
    );
    t.print();

    let res = RobustnessResult { cells };
    println!(
        "  ASM beats every static baseline at {}/{} intensity levels",
        res.asm_win_levels(),
        INTENSITIES.len()
    );
    res
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::OnceLock;

    fn result() -> &'static RobustnessResult {
        static RES: OnceLock<RobustnessResult> = OnceLock::new();
        RES.get_or_init(run)
    }

    #[test]
    fn two_phase_recovers_more_than_static_baselines() {
        let res = result();
        for c in &res.cells {
            assert!(
                c.recovered_frac > 0.0 && c.recovered_frac < 2.0,
                "{:?} @ {}: fraction {} out of range",
                c.model,
                c.intensity,
                c.recovered_frac
            );
        }
        assert!(
            res.asm_win_levels() >= 2,
            "ASM must recover a strictly higher fraction than every \
             static baseline at >= 2 intensity levels: {:?}",
            res.cells
                .iter()
                .map(|c| (c.model.label(), c.intensity, c.recovered_frac))
                .collect::<Vec<_>>()
        );
    }

    #[test]
    fn faults_actually_bite() {
        let res = result();
        // at full intensity nobody keeps all of their clean throughput
        for &m in &MODELS {
            let f = res.frac(m, 1.0);
            assert!(f < 1.0, "{m:?} unscathed at intensity 1.0: {f}");
        }
    }
}
