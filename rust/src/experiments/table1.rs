//! Table 1: system specification of the experimental environments.

use crate::sim::profile::NetProfile;
use crate::util::table::Table;

pub fn run() -> Table {
    let mut t = Table::new(&[
        "profile",
        "bandwidth",
        "rtt",
        "tcp-buffer",
        "disk-bw",
        "cores",
        "max-param",
    ]);
    for p in NetProfile::all() {
        t.row(&[
            p.name.to_string(),
            format!("{:.0} Mbps", p.bandwidth_mbps),
            format!("{:.1} ms", p.rtt_s * 1e3),
            format!("{:.0} MB", p.tcp_buf_mb),
            format!("{:.0} MB/s", p.disk_mbps / 8.0),
            p.cores.to_string(),
            p.max_param.to_string(),
        ]);
    }
    println!("Table 1 — testbed profiles (paper values; see DESIGN.md §2)");
    t.print();
    t
}

#[cfg(test)]
mod tests {
    #[test]
    fn renders_all_profiles() {
        let t = super::run();
        let s = t.render();
        for name in ["xsede", "didclab", "didclab-xsede", "chameleon"] {
            assert!(s.contains(name));
        }
        assert!(s.contains("10000 Mbps"));
        assert!(s.contains("40.0 ms"));
    }
}
