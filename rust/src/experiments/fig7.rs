//! Figure 7: convergence of the dynamic-tuning model — per-chunk
//! throughput over the first chunks of a transfer for ASM vs the
//! feedback-driven baselines (HARP, NMT).  ASM jumps to near-optimal
//! in ≤ ⌈log₂ η⌉ samples; NMT wanders for many epochs.

use crate::baselines::api::OptimizerKind;
use crate::experiments::common::{ctx, request, OFFPEAK_PHASE_S};
use crate::sim::dataset::FileSizeClass;
use crate::sim::engine::SimEnv;
use crate::sim::profile::NetProfile;
use crate::sim::traffic::TrafficProcess;
use crate::util::table::Table;

pub struct Fig7Series {
    pub model: OptimizerKind,
    /// per-chunk measured throughput (Mbps)
    pub series: Vec<f64>,
}

pub struct Fig7Result {
    pub series: Vec<Fig7Series>,
    pub optimal_mbps: f64,
}

const CHUNKS: usize = 14;

pub fn run() -> Fig7Result {
    let c = ctx();
    let profile = NetProfile::xsede();

    // ground-truth optimum at the off-peak load for reference
    let mut probe_env = SimEnv::new(profile.clone(), 1).with_phase(OFFPEAK_PHASE_S);
    let load = probe_env.load_now();
    let dataset = crate::experiments::common::dataset_for(FileSizeClass::Large, 0);
    let optimal_mbps = probe_env.model.true_optimum(&dataset, &load).1;
    let _ = TrafficProcess::fixed(&profile, 0.1);

    let mut all = Vec::new();
    for model in [
        OptimizerKind::Asm,
        OptimizerKind::Harp,
        OptimizerKind::NelderMead,
        OptimizerKind::NoOpt,
    ] {
        let req = request(900, &profile, FileSizeClass::Large, model, false, 0);
        let report = c.orchestrator.execute(&req);
        // the report's outcome isn't kept; re-run capturing the series
        let mut env = SimEnv::new(req.profile.clone(), req.seed).with_phase(req.phase_s);
        let mut opt = c.orchestrator.build_optimizer(&req);
        let mut series = Vec::with_capacity(CHUNKS);
        let mut last = None;
        let mut prev = None;
        for _ in 0..CHUNKS {
            let params = opt.next_params(last);
            let chunk = req.dataset.sample_chunk(0.01);
            let (th, _) = env.transfer_chunk(params, &chunk, prev);
            series.push(th);
            last = Some(th);
            prev = Some(params);
        }
        let _ = report;
        all.push(Fig7Series { model, series });
    }

    let mut t = Table::new(&["chunk", "ASM", "HARP", "NMT", "NoOpt", "optimal"]);
    for i in 0..CHUNKS {
        t.row(&[
            (i + 1).to_string(),
            format!("{:.0}", all[0].series[i]),
            format!("{:.0}", all[1].series[i]),
            format!("{:.0}", all[2].series[i]),
            format!("{:.0}", all[3].series[i]),
            format!("{optimal_mbps:.0}"),
        ]);
    }
    println!("Figure 7 — convergence of dynamic tuning (Mbps per chunk, XSEDE, large)");
    t.print();

    Fig7Result {
        series: all,
        optimal_mbps,
    }
}
