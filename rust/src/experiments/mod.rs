//! One driver per paper table/figure (DESIGN.md §5 maps each to its
//! bench target).  The benches in `rust/benches/exp_*.rs` and the CLI
//! `experiment` subcommand call these; every driver prints the same
//! rows/series the paper reports and returns structured results so
//! tests can assert the *shape* (who wins, by roughly what factor).

pub mod common;
pub mod fig1;
pub mod fig4a;
pub mod fig4b;
pub mod fig5;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod fig9;
pub mod robustness;
pub mod table1;
