//! Figure 6: model accuracy vs offline-analysis staleness — how often
//! must the offline phase re-run?
//!
//! The paper measured 92% accuracy with daily analysis, degrading to
//! ~87% at ten-day staleness.  Staleness only matters if the network
//! *drifts*, so the experiment generates a history on a slowly
//! drifting path (background load grows a few percent per day — usage
//! growth), builds one knowledge base per staleness d from logs that
//! end d days before the evaluation day, and measures the ASM's Eq-21
//! accuracy on fresh transfers.

use crate::baselines::api::{AsmOptimizer, Optimizer};
use crate::coordinator::metrics::accuracy_pct;
use crate::logs::generator::{generate_history, GeneratorConfig};
use crate::logs::schema::LogEntry;
use crate::offline::pipeline::{KnowledgeBase, OfflineConfig};
use crate::online::controller::DynamicTuner;
use crate::sim::dataset::Dataset;
use crate::sim::engine::SimEnv;
use crate::sim::profile::NetProfile;
use crate::util::stats;
use crate::util::table::Table;

/// Daily multiplicative growth of background load on the drifting path.
const DRIFT_PER_DAY: f64 = 0.04;
/// Evaluation happens on this day; KBs are built from logs ending at
/// `EVAL_DAY - d`.
const EVAL_DAY: f64 = 20.0;

/// The drifted profile at a given day.
pub fn profile_at_day(day: f64) -> NetProfile {
    let mut p = NetProfile::xsede();
    let g = 1.0 + DRIFT_PER_DAY * day;
    p.bg_streams_peak *= g;
    p.bg_streams_offpeak *= g;
    p
}

/// Drifting history: day-long windows generated on the day's profile.
fn drifting_history(days: f64, seed: u64) -> Vec<LogEntry> {
    let mut out = Vec::new();
    let mut day = 0.0;
    while day < days {
        let p = profile_at_day(day);
        let mut logs = generate_history(
            &p,
            &GeneratorConfig {
                days: 1.0,
                transfers_per_hour: 24.0,
                seed: seed ^ (day as u64),
            },
        );
        for e in &mut logs {
            e.timestamp_s += day * 86_400.0;
        }
        out.extend(logs);
        day += 1.0;
    }
    out
}

pub struct Fig6Result {
    /// (staleness days, mean accuracy %)
    pub points: Vec<(usize, f64)>,
}

pub fn run() -> Fig6Result {
    let history = drifting_history(EVAL_DAY, 0x46c);
    let eval_profile = profile_at_day(EVAL_DAY);
    let dataset = Dataset::new(128, 256.0);

    let mut points = Vec::new();
    for d in [1usize, 2, 4, 6, 8, 10] {
        // logs available to a KB refreshed d days ago; the periodic
        // analysis consumes the most recent ten days of logs (the
        // additive window), so staleness shifts the window back by d
        let cutoff = (EVAL_DAY - d as f64) * 86_400.0;
        let window_start = cutoff - 10.0 * 86_400.0;
        let visible: Vec<LogEntry> = history
            .iter()
            .filter(|e| e.timestamp_s >= window_start && e.timestamp_s < cutoff)
            .cloned()
            .collect();
        let kb = KnowledgeBase::build_native(visible, OfflineConfig::default());

        // fresh transfers on the drifted network, per-seed accuracy
        let mut accs = Vec::new();
        for seed in 0..10u64 {
            let set = kb
                .query(
                    eval_profile.rtt_s,
                    eval_profile.bandwidth_mbps,
                    dataset.avg_file_mb,
                    dataset.n_files,
                )
                .expect("kb has surfaces")
                .clone();
            let mut opt = AsmOptimizer::new(DynamicTuner::with_defaults(set));
            let mut env =
                SimEnv::new(eval_profile.clone(), 0x5EED ^ seed).with_phase(10.0 * 3600.0);
            let mut last = None;
            let mut prev = None;
            // sampling + a few streaming chunks to converge
            let mut params = opt.next_params(None);
            for _ in 0..8 {
                let chunk = dataset.sample_chunk(0.02);
                let (th, _) = env.transfer_chunk(params, &chunk, prev);
                last = Some(th);
                prev = Some(params);
                params = opt.next_params(last);
            }
            // penalty-free steady measurement at the converged point,
            // averaged over several samples to beat measurement noise
            let load = env.load_now();
            let achieved = (0..10)
                .map(|_| env.model.sample(params, &dataset, &load, &mut env.rng))
                .sum::<f64>()
                / 10.0;
            let predicted = opt.predicted_th().unwrap_or(achieved);
            accs.push(accuracy_pct(achieved, predicted));
        }
        points.push((d, stats::mean(&accs)));
    }

    let mut t = Table::new(&["offline period (days)", "accuracy"]);
    for (d, a) in &points {
        t.row(&[d.to_string(), format!("{a:.1}%")]);
    }
    println!("Figure 6 — accuracy vs offline analysis staleness (drifting path)");
    t.print();
    println!("  paper: 92% daily -> ~87% at 10 days");

    Fig6Result { points }
}
