//! Figure 5(a–i): achievable throughput of all models across three
//! networks × three dataset classes × peak/off-peak hours.

use crate::baselines::api::OptimizerKind;
use crate::experiments::common::{ctx, par_cells, reps, request};
use crate::sim::dataset::FileSizeClass;
use crate::sim::profile::NetProfile;
use crate::util::stats;
use crate::util::table::Table;

/// One cell of the Fig 5 matrix.
#[derive(Debug, Clone)]
pub struct Fig5Cell {
    pub network: &'static str,
    pub class: FileSizeClass,
    pub peak: bool,
    pub model: OptimizerKind,
    pub mean_throughput_mbps: f64,
}

pub struct Fig5Result {
    pub cells: Vec<Fig5Cell>,
}

impl Fig5Result {
    pub fn cell(
        &self,
        network: &str,
        class: FileSizeClass,
        peak: bool,
        model: OptimizerKind,
    ) -> Option<&Fig5Cell> {
        self.cells.iter().find(|c| {
            c.network == network && c.class == class && c.peak == peak && c.model == model
        })
    }

    /// ASM / HARP ratio for one (network, class, peak) panel.
    pub fn asm_vs_harp(&self, network: &str, class: FileSizeClass, peak: bool) -> f64 {
        let asm = self
            .cell(network, class, peak, OptimizerKind::Asm)
            .map(|c| c.mean_throughput_mbps)
            .unwrap_or(0.0);
        let harp = self
            .cell(network, class, peak, OptimizerKind::Harp)
            .map(|c| c.mean_throughput_mbps)
            .unwrap_or(1.0);
        asm / harp.max(1e-9)
    }
}

/// Models evaluated in Fig 5 (the paper's seven, in its order).
pub fn fig5_models() -> [OptimizerKind; 7] {
    [
        OptimizerKind::Asm,
        OptimizerKind::Harp,
        OptimizerKind::AnnOt,
        OptimizerKind::NelderMead,
        OptimizerKind::SingleChunk,
        OptimizerKind::StaticAnn,
        OptimizerKind::Globus,
    ]
}

pub fn networks() -> [NetProfile; 3] {
    [
        NetProfile::xsede(),
        NetProfile::didclab(),
        NetProfile::didclab_xsede(),
    ]
}

pub fn run() -> Fig5Result {
    let c = ctx();
    let r = reps();
    let mut units = Vec::new();
    for profile in networks() {
        for class in FileSizeClass::all() {
            for peak in [false, true] {
                for model in fig5_models() {
                    units.push((profile.clone(), class, peak, model));
                }
            }
        }
    }
    // every request id is a pure function of (cell index, rep) —
    // exactly the sequence the old serial nested loop handed out — so
    // the fan-out is bit-identical at any thread count
    let cells: Vec<Fig5Cell> = par_cells(&units, |ci, (profile, class, peak, model)| {
        let mut ths = Vec::with_capacity(r);
        for rep in 0..r {
            let id = (ci * r + rep) as u64 + 1;
            let req = request(id, profile, *class, *model, *peak, rep);
            let report = c.orchestrator.execute(&req);
            // the paper reports end-to-end achieved throughput: total
            // bytes / total wall time, sampling and re-tuning overhead
            // included
            ths.push(report.avg_throughput_mbps);
        }
        Fig5Cell {
            network: profile.name,
            class: *class,
            peak: *peak,
            model: *model,
            mean_throughput_mbps: stats::mean(&ths),
        }
    });

    // print one paper-style panel table per network
    for profile in networks() {
        let mut t = Table::new(&[
            "dataset", "hours", "ASM", "HARP", "ANN+OT", "NMT", "SC", "SP", "GO",
        ]);
        for class in FileSizeClass::all() {
            for peak in [false, true] {
                let mut row = vec![
                    class.name().to_string(),
                    if peak { "peak" } else { "off-peak" }.to_string(),
                ];
                for model in fig5_models() {
                    let v = cells
                        .iter()
                        .find(|cl| {
                            cl.network == profile.name
                                && cl.class == class
                                && cl.peak == peak
                                && cl.model == model
                        })
                        .map(|cl| cl.mean_throughput_mbps)
                        .unwrap_or(0.0);
                    row.push(format!("{v:.0}"));
                }
                t.row(&row);
            }
        }
        println!(
            "Figure 5 — mean steady throughput (Mbps), network = {}",
            profile.name
        );
        t.print();
    }

    let res = Fig5Result { cells };
    // headline ratios
    for profile in networks() {
        for class in FileSizeClass::all() {
            let ratio = res.asm_vs_harp(profile.name, class, false);
            println!(
                "  {} / {}: ASM vs HARP (off-peak) = {ratio:.2}x",
                profile.name,
                class.name()
            );
        }
    }
    res
}
