//! The steady-state throughput function — the simulator's answer to the
//! paper's Eq 1: `th = f(e_s, e_d, b, rtt, f_avg, n, cc, p, pp, l_ctd)`.
//!
//! Composition (each factor documented on its helper):
//!
//! 1. uncongested per-stream rate `r₀ = min(buf/RTT, Mathis(base loss))`;
//! 2. congestion pressure `u = (s_total + bg) · r₀ / B` raises loss
//!    above the ~92% knee (`tcp::congestion_loss`), and the Mathis
//!    response to that loss throttles every stream — the feedback that
//!    penalizes opening excessive streams on long-RTT paths;
//! 3. TCP-fair share of the bottleneck (`B · s / (s + bg)`) caps the
//!    aggregate against background streams (`l_ctd`);
//! 4. per-stream window thrash: once the per-stream BDP slice drops to
//!    a few MSS, fast retransmit stops working and streams stall —
//!    the dominant penalty on short-RTT/low-BDP paths like DIDCLAB;
//! 5. end-system overhead: stream bookkeeping `1/(1 + a·s^1.5)`, core
//!    over-subscription when `cc > cores`, and disk/NIC caps;
//! 6. the control-channel factor: each file costs one acknowledgement
//!    RTT amortized by pipelining (`rtt / min(pp, files-per-channel)`),
//!    plus a mild per-slot queue-management cost that keeps `pp`
//!    bounded;
//! 7. the parallelism fragmentation factor: splitting small files into
//!    `p` streams wastes their tails (why parallelism only pays for
//!    medium/large files, §2).

use crate::sim::dataset::Dataset;
use crate::sim::profile::NetProfile;
use crate::sim::tcp;
use crate::sim::traffic::LoadState;
use crate::util::rng::Rng;
use crate::Params;

/// Demand-pressure ceiling: beyond 1.5× capacity the extra pressure no
/// longer changes equilibrium loss (queues are already overflowing).
const PRESSURE_CAP: f64 = 1.5;
/// Per-extra-stream per-file fragmentation overhead (MB-equivalent).
const FRAG_MB: f64 = 0.5;
/// Queue-management cost per pipelining slot (fraction of an RTT).
const PP_SLOT_COST: f64 = 0.001;
/// Stream-bookkeeping overhead coefficient (factor 1/(1 + a·s^1.5)).
const SYS_OVERHEAD_A: f64 = 2e-4;
/// Window-thrash scale in MSS units.
const THRASH_MSS: f64 = 0.5;
/// Multiplicative lognormal noise σ for sampled (measured) throughput.
pub const SAMPLE_SIGMA: f64 = 0.05;

/// Deterministic throughput model over one network profile.
///
/// Profile-derived constants (uncongested per-stream rate, saturation
/// stream count, BDP, overload γ) are cached at construction: `steady`
/// sits on the innermost loop of every experiment and the grid scans of
/// `true_optimum` (§Perf iteration 1 in EXPERIMENTS.md).
#[derive(Debug, Clone)]
pub struct ThroughputModel {
    pub profile: NetProfile,
    /// per-stream rate at base loss (window/Mathis/link min)
    r0_base: f64,
    /// streams needed to saturate the bottleneck at base loss
    s_sat: f64,
    /// path BDP in bytes (window-thrash scale)
    bdp_bytes: f64,
    /// RTT-scaled overload coefficient
    gamma: f64,
}

impl ThroughputModel {
    pub fn new(profile: NetProfile) -> ThroughputModel {
        let r0_base = tcp::stream_rate_mbps(&profile, profile.base_loss);
        let s_sat = (profile.bandwidth_mbps / r0_base).max(1.0);
        let bdp_bytes = profile.bandwidth_mbps * 1e6 * profile.rtt_s / 8.0;
        let gamma = 0.12 * (profile.rtt_s / 0.020).min(1.0);
        ThroughputModel {
            profile,
            r0_base,
            s_sat,
            bdp_bytes,
            gamma,
        }
    }

    /// Loss probability when `total_streams` streams (ours + background)
    /// press on the bottleneck: congestion pressure is the utilization
    /// the streams *would* reach at their uncongested rate, capped at
    /// [`PRESSURE_CAP`].
    pub fn pressure_loss(&self, total_streams: f64) -> f64 {
        let p = &self.profile;
        let u = (total_streams * self.r0_base / p.bandwidth_mbps).min(PRESSURE_CAP);
        tcp::congestion_loss(p.base_loss, u * p.bandwidth_mbps, p.bandwidth_mbps)
    }

    /// Per-stream window-thrash factor: the share of the path's BDP
    /// available to each stream, in MSS units, saturating to 1 when
    /// streams have room (`w / (w + 0.5·MSS)`).
    pub fn thrash_factor(&self, total_streams: f64) -> f64 {
        let w = self.bdp_bytes / total_streams.max(1.0);
        w / (w + THRASH_MSS * self.profile.mss_bytes)
    }

    /// Stream-bookkeeping overhead factor for `s` own streams.
    pub fn sys_factor(&self, s: f64) -> f64 {
        1.0 / (1.0 + SYS_OVERHEAD_A * s.powf(1.5))
    }

    /// Streams needed to saturate the bottleneck at base loss.
    pub fn saturation_streams(&self) -> f64 {
        self.s_sat
    }

    /// Aggregate overload goodput factor: opening streams far beyond
    /// the saturation point floods the bottleneck queue — RTT inflates,
    /// retransmissions burn capacity, and *everyone's* goodput decays
    /// exponentially in the overload ratio.  Scaled by RTT: long-RTT
    /// paths pay full price (loss recovery is slow), LAN-RTT paths
    /// barely notice.  This is the mechanism that makes statically
    /// aggressive parameter choices (the paper's HARP-in-contention
    /// case, §5.4) hurt, and gives heavy-load surfaces their moderate
    /// optima.
    pub fn overload_factor(&self, total_streams: f64) -> f64 {
        let ratio = total_streams / self.s_sat;
        (-self.gamma * (ratio - 1.0).max(0.0)).exp()
    }

    /// Steady-state end-to-end throughput in Mbps.
    pub fn steady(&self, params: Params, dataset: &Dataset, load: &LoadState) -> f64 {
        let p = &self.profile;
        let params = params.clamp(p.max_param);
        let s = params.total_streams() as f64;
        let total = s + load.bg_streams;

        // (1)-(2) per-stream rate under congestion-pressure loss
        let lambda = self.pressure_loss(total);
        let r = tcp::stream_rate_mbps(p, lambda);

        // (3) aggregate: own streams vs TCP-fair share of the bottleneck
        let share = p.bandwidth_mbps * s / total.max(1.0);
        let mut agg = (s * r).min(share).min(p.bandwidth_mbps);

        // (4) window thrash on low-BDP paths + aggregate overload
        agg *= self.thrash_factor(total);
        agg *= self.overload_factor(total);

        // (5) end-system: stream bookkeeping, cores, disk, NIC
        agg *= self.sys_factor(s);
        if params.cc > p.cores {
            agg *= (p.cores as f64 / params.cc as f64).powf(0.4);
        }
        agg = agg.min(p.disk_mbps).min(p.nic_mbps);

        // (6) control-channel (pipelining) factor, per channel
        let files_per_ch = (dataset.n_files as f64 / params.cc as f64).max(1.0);
        let ch_rate = agg / params.cc as f64; // Mbps per channel
        let data_time_per_file = dataset.avg_file_mb * 8.0 / ch_rate.max(1e-9);
        let pp_eff = (params.pp as f64).min(files_per_ch).max(1.0);
        let ack_time_per_file =
            p.rtt_s / pp_eff + PP_SLOT_COST * params.pp as f64 * p.rtt_s;
        let ctrl_factor = data_time_per_file / (data_time_per_file + ack_time_per_file);

        // (7) parallelism fragmentation on small files
        let frag_factor =
            dataset.avg_file_mb / (dataset.avg_file_mb + (params.p as f64 - 1.0) * FRAG_MB);

        agg * ctrl_factor * frag_factor
    }

    /// One *measured* throughput sample: steady state with lognormal
    /// measurement/route noise (the deviation the paper's Gaussian
    /// confidence regions absorb, Fig 4a).
    pub fn sample(
        &self,
        params: Params,
        dataset: &Dataset,
        load: &LoadState,
        rng: &mut Rng,
    ) -> f64 {
        let th = self.steady(params, dataset, load);
        th * rng.lognormal(0.0, SAMPLE_SIGMA)
    }

    /// Dead time charged when switching `from -> to` mid-transfer:
    /// process startup for new channels plus slow-start ramp for every
    /// newly-opened stream's share (§4.2: "if a cc value changes from 2
    /// to 4, this algorithm has to open two more server processes ...
    /// new processes have to go through TCP slow start").
    pub fn param_change_penalty_s(&self, from: Params, to: Params) -> f64 {
        if from == to {
            return 0.0;
        }
        let p = &self.profile;
        let new_procs = to.cc.saturating_sub(from.cc) as f64;
        let new_streams = to.total_streams().saturating_sub(from.total_streams()) as f64;
        let proc_cost = 0.10 * new_procs; // fork + auth + channel setup
        let lambda = self.pressure_loss(to.total_streams() as f64);
        let r = tcp::stream_rate_mbps(p, lambda);
        let ss = tcp::slow_start_penalty_s(p, r) * new_streams.min(16.0);
        // pipelining-only changes are nearly free
        proc_cost + ss
    }

    /// True optimum over the bounded integer domain Ψ³ by exhaustive
    /// scan (ground truth for accuracy experiments; the paper can only
    /// estimate this on real networks).
    pub fn true_optimum(&self, dataset: &Dataset, load: &LoadState) -> (Params, f64) {
        let grid = [1u32, 2, 3, 4, 6, 8, 12, 16, 24, 32];
        let mut best = (Params::DEFAULT, 0.0);
        for &cc in &grid {
            for &p in &grid {
                for &pp in &grid {
                    let params = Params::new(cc, p, pp);
                    let th = self.steady(params, dataset, load);
                    if th > best.1 {
                        best = (params, th);
                    }
                }
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::traffic::TrafficProcess;

    fn setup(name: &str) -> (ThroughputModel, LoadState) {
        let p = NetProfile::by_name(name).unwrap();
        let l = TrafficProcess::fixed(&p, 0.2);
        (ThroughputModel::new(p), l)
    }

    fn large() -> Dataset {
        Dataset::new(64, 1024.0)
    }

    fn small() -> Dataset {
        Dataset::new(20_000, 1.0)
    }

    #[test]
    fn throughput_never_exceeds_link_or_disk() {
        for name in ["xsede", "didclab", "didclab-xsede", "chameleon"] {
            let (m, l) = setup(name);
            for cc in [1u32, 4, 16, 32] {
                for p in [1u32, 4, 16] {
                    for pp in [1u32, 8, 32] {
                        let th = m.steady(Params::new(cc, p, pp), &large(), &l);
                        assert!(th >= 0.0);
                        assert!(th <= m.profile.bandwidth_mbps + 1e-9);
                        assert!(th <= m.profile.disk_mbps + 1e-9);
                    }
                }
            }
        }
    }

    #[test]
    fn parallel_streams_help_on_long_rtt() {
        let (m, l) = setup("xsede");
        let one = m.steady(Params::new(1, 1, 4), &large(), &l);
        let many = m.steady(Params::new(8, 4, 4), &large(), &l);
        assert!(many > 3.0 * one, "one={one} many={many}");
    }

    #[test]
    fn excessive_streams_hurt() {
        // interior maximum: th at β_max below the best interior point
        let (m, l) = setup("didclab-xsede");
        let best = m.true_optimum(&large(), &l).1;
        let maxed = m.steady(Params::new(32, 32, 4), &large(), &l);
        assert!(
            maxed < 0.9 * best,
            "no interior max: maxed={maxed} best={best}"
        );
    }

    #[test]
    fn pipelining_dominates_small_files() {
        let (m, l) = setup("xsede");
        let no_pp = m.steady(Params::new(4, 1, 1), &small(), &l);
        let pp = m.steady(Params::new(4, 1, 16), &small(), &l);
        assert!(pp > 2.0 * no_pp, "no_pp={no_pp} pp={pp}");
    }

    #[test]
    fn pipelining_irrelevant_for_large_files() {
        let (m, l) = setup("xsede");
        let a = m.steady(Params::new(4, 4, 1), &large(), &l);
        let b = m.steady(Params::new(4, 4, 16), &large(), &l);
        assert!((a - b).abs() / a < 0.05, "a={a} b={b}");
    }

    #[test]
    fn parallelism_hurts_small_files() {
        let (m, l) = setup("xsede");
        let p1 = m.steady(Params::new(8, 1, 16), &small(), &l);
        let p8 = m.steady(Params::new(8, 8, 16), &small(), &l);
        assert!(p1 > p8, "p1={p1} p8={p8}");
    }

    #[test]
    fn higher_background_load_lowers_throughput() {
        let p = NetProfile::xsede();
        let m = ThroughputModel::new(p.clone());
        let light = TrafficProcess::fixed(&p, 0.05);
        let heavy = TrafficProcess::fixed(&p, 0.9);
        let params = Params::new(8, 4, 8);
        let th_l = m.steady(params, &large(), &light);
        let th_h = m.steady(params, &large(), &heavy);
        assert!(th_h < 0.8 * th_l, "light={th_l} heavy={th_h}");
    }

    #[test]
    fn optimum_shifts_with_load() {
        let p = NetProfile::didclab_xsede();
        let m = ThroughputModel::new(p.clone());
        let light = TrafficProcess::fixed(&p, 0.05);
        let heavy = TrafficProcess::fixed(&p, 0.95);
        let (opt_l, _) = m.true_optimum(&large(), &light);
        let (opt_h, _) = m.true_optimum(&large(), &heavy);
        assert_ne!(
            opt_l, opt_h,
            "optimal params should depend on external load"
        );
    }

    #[test]
    fn pressure_loss_monotone_in_streams() {
        let (m, _) = setup("xsede");
        let mut prev = 0.0;
        for &streams in &[1.0, 16.0, 64.0, 256.0, 1024.0] {
            let lam = m.pressure_loss(streams);
            assert!(lam >= prev - 1e-15, "loss must not drop with pressure");
            assert!(lam >= m.profile.base_loss && lam <= 0.5);
            prev = lam;
        }
    }

    #[test]
    fn thrash_negligible_on_high_bdp_paths() {
        let (mx, _) = setup("xsede"); // BDP 50 MB
        assert!(mx.thrash_factor(1036.0) > 0.97);
        let (md, _) = setup("didclab"); // BDP 25 KB
        assert!(md.thrash_factor(16.0) < 0.75);
    }

    #[test]
    fn sampled_noise_is_centred() {
        let (m, l) = setup("xsede");
        let mut rng = Rng::new(5);
        let params = Params::new(8, 4, 8);
        let truth = m.steady(params, &large(), &l);
        let n = 500;
        let mean: f64 = (0..n)
            .map(|_| m.sample(params, &large(), &l, &mut rng))
            .sum::<f64>()
            / n as f64;
        assert!((mean / truth - 1.0).abs() < 0.03, "mean={mean} truth={truth}");
    }

    #[test]
    fn param_change_penalty_shape() {
        let (m, _) = setup("xsede");
        let same = m.param_change_penalty_s(Params::new(4, 4, 4), Params::new(4, 4, 4));
        assert_eq!(same, 0.0);
        let pp_only = m.param_change_penalty_s(Params::new(4, 4, 4), Params::new(4, 4, 16));
        let grow = m.param_change_penalty_s(Params::new(4, 4, 4), Params::new(8, 4, 4));
        let shrink = m.param_change_penalty_s(Params::new(8, 4, 4), Params::new(4, 4, 4));
        assert!(pp_only < 0.01, "pp change should be ~free: {pp_only}");
        assert!(grow > 0.3, "new processes must cost: {grow}");
        assert!(shrink < grow, "shrinking is cheaper than growing");
    }

    #[test]
    fn didclab_is_disk_bound() {
        let (m, l) = setup("didclab");
        let (_, best) = m.true_optimum(&large(), &l);
        assert!(best <= m.profile.disk_mbps + 1e-9);
        assert!(best > 0.6 * m.profile.disk_mbps, "best={best}");
    }
}
