//! Datasets: the paper partitions transfer requests into small, medium
//! and large average-file-size classes (§5.1) because achievable
//! throughput depends strongly on `f_avg` and `n`.

use crate::util::rng::Rng;

/// File-size class used throughout §5's evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FileSizeClass {
    /// ~0.5–10 MB files: control-channel (pipelining) dominated.
    Small,
    /// ~10–256 MB: mixed regime.
    Medium,
    /// ~0.25–8 GB: stream (parallelism/concurrency) dominated.
    Large,
}

impl FileSizeClass {
    pub fn all() -> [FileSizeClass; 3] {
        [Self::Small, Self::Medium, Self::Large]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Small => "small",
            Self::Medium => "medium",
            Self::Large => "large",
        }
    }

    /// Average-file-size bounds (MB) for classification.
    pub fn bounds_mb(&self) -> (f64, f64) {
        match self {
            Self::Small => (0.1, 10.0),
            Self::Medium => (10.0, 256.0),
            Self::Large => (256.0, 16_384.0),
        }
    }

    pub fn classify(avg_file_mb: f64) -> FileSizeClass {
        if avg_file_mb < 10.0 {
            Self::Small
        } else if avg_file_mb < 256.0 {
            Self::Medium
        } else {
            Self::Large
        }
    }
}

/// A transfer request's data description (the `data_args` of
/// Algorithm 1): total volume is implied by `n_files * avg_file_mb`.
#[derive(Debug, Clone, PartialEq)]
pub struct Dataset {
    pub n_files: u64,
    pub avg_file_mb: f64,
}

impl Dataset {
    pub fn new(n_files: u64, avg_file_mb: f64) -> Dataset {
        assert!(n_files > 0 && avg_file_mb > 0.0);
        Dataset {
            n_files,
            avg_file_mb,
        }
    }

    pub fn total_mb(&self) -> f64 {
        self.n_files as f64 * self.avg_file_mb
    }

    pub fn class(&self) -> FileSizeClass {
        FileSizeClass::classify(self.avg_file_mb)
    }

    /// Draw a random dataset of the given class (sizes log-uniform in
    /// the class bounds; file counts sized so totals stay comparable).
    pub fn sample(class: FileSizeClass, rng: &mut Rng) -> Dataset {
        let (lo, hi) = class.bounds_mb();
        let avg = (rng.uniform(lo.ln(), hi.ln())).exp();
        // target total volume 2–64 GB
        let total_mb = rng.uniform(2_048.0, 65_536.0);
        let n = ((total_mb / avg).round() as u64).max(4);
        Dataset::new(n, avg)
    }

    /// Split off a sample-transfer chunk of roughly `frac` of the data
    /// (Algorithm 1 performs sample transfers on a "small predefined
    /// portion of the data").
    pub fn sample_chunk(&self, frac: f64) -> Dataset {
        let files = ((self.n_files as f64 * frac).ceil() as u64).clamp(1, self.n_files);
        Dataset::new(files, self.avg_file_mb)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classification_matches_bounds() {
        assert_eq!(FileSizeClass::classify(1.0), FileSizeClass::Small);
        assert_eq!(FileSizeClass::classify(100.0), FileSizeClass::Medium);
        assert_eq!(FileSizeClass::classify(1000.0), FileSizeClass::Large);
    }

    #[test]
    fn sampled_datasets_stay_in_class() {
        let mut rng = Rng::new(1);
        for class in FileSizeClass::all() {
            for _ in 0..50 {
                let d = Dataset::sample(class, &mut rng);
                assert_eq!(d.class(), class, "{d:?}");
                assert!(d.n_files >= 4);
            }
        }
    }

    #[test]
    fn sample_chunk_bounds() {
        let d = Dataset::new(1000, 5.0);
        let c = d.sample_chunk(0.01);
        assert_eq!(c.n_files, 10);
        assert_eq!(d.sample_chunk(2.0).n_files, 1000);
        assert_eq!(Dataset::new(3, 5.0).sample_chunk(0.001).n_files, 1);
    }

    #[test]
    fn total_volume() {
        assert_eq!(Dataset::new(100, 2.5).total_mb(), 250.0);
    }
}
