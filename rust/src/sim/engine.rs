//! The single-job simulation engine: a clock, a traffic process and a
//! throughput model, executing chunked transfers under a pluggable
//! per-chunk parameter policy.  Every optimizer (ASM and the six
//! baselines) runs against this same engine in the experiments.

use crate::faults::{FaultEngine, FaultPlan, FaultState};
use crate::sim::dataset::Dataset;
use crate::sim::profile::NetProfile;
use crate::sim::traffic::{LoadState, TrafficProcess};
use crate::sim::transfer::ThroughputModel;
use crate::util::rng::Rng;
use crate::Params;

/// Wall-clock cost of noticing an unresponsive endpoint (connection /
/// control-channel timeout) before a chunk attempt is abandoned.
pub const STALL_DETECT_S: f64 = 5.0;

/// Why a fallible chunk attempt failed (see
/// [`SimEnv::try_transfer_chunk`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ChunkFault {
    /// The endpoint is stalled; no data moved.  `resume_at_s` is when
    /// the underlying fault clears (the coordinator does not get to see
    /// this — its retry/backoff schedule is its own — but tests do).
    EndpointStall { resume_at_s: f64 },
}

/// Context handed to the policy before each chunk.
#[derive(Debug, Clone, Copy)]
pub struct ChunkCtx {
    pub chunk_idx: usize,
    /// seconds since the transfer started
    pub elapsed_s: f64,
    /// measured throughput of the previous chunk (None on the first)
    pub last_throughput: Option<f64>,
    pub last_params: Option<Params>,
    pub remaining_mb: f64,
}

/// One per-chunk measurement record.
#[derive(Debug, Clone, Copy)]
pub struct ChunkSample {
    pub t_s: f64,
    pub params: Params,
    pub throughput_mbps: f64,
    pub chunk_mb: f64,
    /// dead time charged for the parameter change before this chunk
    pub penalty_s: f64,
}

/// Result of a full simulated transfer.
#[derive(Debug, Clone)]
pub struct TransferOutcome {
    pub total_mb: f64,
    pub duration_s: f64,
    pub samples: Vec<ChunkSample>,
}

impl TransferOutcome {
    /// Volume-weighted average end-to-end throughput in Mbps.
    pub fn avg_throughput_mbps(&self) -> f64 {
        if self.duration_s <= 0.0 {
            return 0.0;
        }
        self.total_mb * 8.0 / self.duration_s
    }

    pub fn param_changes(&self) -> usize {
        self.samples
            .windows(2)
            .filter(|w| w[0].params != w[1].params)
            .count()
    }
}

/// Simulation environment for one user on one network.
pub struct SimEnv {
    pub model: ThroughputModel,
    pub traffic: TrafficProcess,
    pub now_s: f64,
    pub rng: Rng,
    /// Optional fault schedule (None = benign network, the historical
    /// behavior, bit-for-bit).
    pub faults: Option<FaultEngine>,
}

impl SimEnv {
    pub fn new(profile: NetProfile, seed: u64) -> SimEnv {
        let traffic = TrafficProcess::new(&profile, seed);
        SimEnv {
            model: ThroughputModel::new(profile),
            traffic,
            now_s: 0.0,
            rng: Rng::new(seed ^ 0x5e55_1015),
            faults: None,
        }
    }

    /// Pin the diurnal phase (peak vs off-peak experiments).
    pub fn with_phase(mut self, phase_s: f64) -> SimEnv {
        self.traffic = self.traffic.with_phase(phase_s);
        self
    }

    /// Inject a fault schedule (fault-plan time 0 = the env's clock 0).
    pub fn with_faults(mut self, plan: FaultPlan) -> SimEnv {
        self.faults = Some(FaultEngine::new(plan));
        self
    }

    /// The combined fault condition at the current clock (clear when no
    /// schedule is installed).
    pub fn fault_state(&self) -> FaultState {
        self.faults
            .as_ref()
            .map(|f| f.state_at(self.now_s))
            .unwrap_or_default()
    }

    /// Sample one chunk's throughput under the current fault state,
    /// held piecewise-constant for the chunk.  Under fault injection
    /// the sample is clamped to the (possibly degraded) link capacity
    /// so that delivered bytes never exceed degraded capacity ×
    /// elapsed time; the benign path keeps its historical unclamped
    /// lognormal noise.
    fn sample_chunk(&mut self, params: Params, chunk: &Dataset, fs: &FaultState) -> f64 {
        let load = self.traffic.at(self.now_s);
        if fs.is_clear() {
            let th = self
                .model
                .sample(params, chunk, &load, &mut self.rng)
                .max(1e-3);
            return match &self.faults {
                Some(_) => th.min(self.model.profile.bandwidth_mbps).max(1e-3),
                None => th,
            };
        }
        let degraded = ThroughputModel::new(fs.degrade(&self.model.profile));
        let load = fs.surge(load, &self.model.profile);
        let cap = degraded.profile.bandwidth_mbps;
        degraded
            .sample(params, chunk, &load, &mut self.rng)
            .min(cap)
            .max(1e-3)
    }

    /// Advance the clock, returning the new load state.
    pub fn advance(&mut self, dt_s: f64) -> LoadState {
        self.now_s += dt_s;
        self.traffic.at(self.now_s)
    }

    pub fn load_now(&mut self) -> LoadState {
        self.traffic.at(self.now_s)
    }

    /// Execute a single sample/chunk transfer at `params`, advancing the
    /// clock by its duration.  Returns (measured Mbps, duration s).
    ///
    /// Infallible: an endpoint stall is simply waited out as dead time
    /// (included in the measured throughput).  Coordinators that want
    /// to retry/back off instead use [`SimEnv::try_transfer_chunk`].
    pub fn transfer_chunk(
        &mut self,
        params: Params,
        chunk: &Dataset,
        prev_params: Option<Params>,
    ) -> (f64, f64) {
        let mut stall_s = 0.0;
        if let Some(until) = self.fault_state().stalled_until_s {
            if until > self.now_s {
                stall_s = until - self.now_s;
                self.now_s = until;
            }
        }
        let fs = self.fault_state();
        let th = self.sample_chunk(params, chunk, &fs);
        let penalty = prev_params
            .map(|prev| self.model.param_change_penalty_s(prev, params))
            .unwrap_or(0.0);
        let duration = chunk.total_mb() * 8.0 / th + penalty + stall_s;
        self.now_s += chunk.total_mb() * 8.0 / th + penalty;
        // measured throughput includes the switch penalty + stall time
        let measured = chunk.total_mb() * 8.0 / duration;
        (measured, duration)
    }

    /// Fallible chunk attempt — the coordinator-facing fault hook.  If
    /// the endpoint is stalled the attempt is abandoned after
    /// [`STALL_DETECT_S`] of wall clock and nothing is transferred;
    /// otherwise this behaves exactly like [`SimEnv::transfer_chunk`].
    pub fn try_transfer_chunk(
        &mut self,
        params: Params,
        chunk: &Dataset,
        prev_params: Option<Params>,
    ) -> Result<(f64, f64), ChunkFault> {
        let fs = self.fault_state();
        if let Some(until) = fs.stalled_until_s {
            if until > self.now_s {
                self.now_s += STALL_DETECT_S;
                return Err(ChunkFault::EndpointStall { resume_at_s: until });
            }
        }
        let th = self.sample_chunk(params, chunk, &fs);
        let penalty = prev_params
            .map(|prev| self.model.param_change_penalty_s(prev, params))
            .unwrap_or(0.0);
        let duration = chunk.total_mb() * 8.0 / th + penalty;
        self.now_s += duration;
        let measured = chunk.total_mb() * 8.0 / duration;
        Ok((measured, duration))
    }

    /// Run a full chunked transfer under `policy` (called before every
    /// chunk with the running context).
    pub fn run_transfer<F>(
        &mut self,
        dataset: &Dataset,
        chunk_mb: f64,
        mut policy: F,
    ) -> TransferOutcome
    where
        F: FnMut(&mut SimEnv, &ChunkCtx) -> Params,
    {
        let total_mb = dataset.total_mb();
        let start = self.now_s;
        let mut remaining_mb = total_mb;
        let mut samples: Vec<ChunkSample> = Vec::new();
        let mut last_params: Option<Params> = None;
        let mut last_th: Option<f64> = None;
        let mut idx = 0usize;

        while remaining_mb > 1e-9 {
            let this_mb = chunk_mb.min(remaining_mb);
            let files = ((this_mb / dataset.avg_file_mb).ceil() as u64).max(1);
            let chunk = Dataset::new(files, this_mb / files as f64);

            let ctx = ChunkCtx {
                chunk_idx: idx,
                elapsed_s: self.now_s - start,
                last_throughput: last_th,
                last_params,
                remaining_mb,
            };
            let params = policy(self, &ctx).clamp(self.model.profile.max_param);
            let penalty = last_params
                .map(|prev| self.model.param_change_penalty_s(prev, params))
                .unwrap_or(0.0);
            // endpoint stalls are waited out as dead time in this
            // infallible path (the resilient coordinator retries instead)
            let mut stall_s = 0.0;
            if let Some(until) = self.fault_state().stalled_until_s {
                if until > self.now_s {
                    stall_s = until - self.now_s;
                    self.now_s = until;
                }
            }
            let fs = self.fault_state();
            let th = self.sample_chunk(params, &chunk, &fs);
            let duration = chunk.total_mb() * 8.0 / th + penalty + stall_s;
            self.now_s += chunk.total_mb() * 8.0 / th + penalty;

            let measured = chunk.total_mb() * 8.0 / duration;
            samples.push(ChunkSample {
                t_s: self.now_s - start,
                params,
                throughput_mbps: measured,
                chunk_mb: chunk.total_mb(),
                penalty_s: penalty,
            });
            remaining_mb -= chunk.total_mb();
            last_params = Some(params);
            last_th = Some(measured);
            idx += 1;
        }

        TransferOutcome {
            total_mb,
            duration_s: self.now_s - start,
            samples,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn env() -> SimEnv {
        SimEnv::new(NetProfile::xsede(), 42).with_phase(0.0)
    }

    #[test]
    fn static_policy_transfers_all_data() {
        let mut e = env();
        let d = Dataset::new(64, 256.0); // 16 GB
        let out = e.run_transfer(&d, 2048.0, |_, _| Params::new(8, 4, 8));
        assert!((out.total_mb - d.total_mb()).abs() < 1e-6);
        let moved: f64 = out.samples.iter().map(|s| s.chunk_mb).sum();
        assert!((moved - d.total_mb()).abs() < 1e-6);
        assert!(out.duration_s > 0.0);
        assert_eq!(out.param_changes(), 0);
    }

    #[test]
    fn avg_throughput_consistent_with_duration() {
        let mut e = env();
        let d = Dataset::new(32, 512.0);
        let out = e.run_transfer(&d, 4096.0, |_, _| Params::new(8, 4, 8));
        let th = out.avg_throughput_mbps();
        assert!((th - out.total_mb * 8.0 / out.duration_s).abs() < 1e-9);
        assert!(th > 100.0, "implausibly slow: {th}");
    }

    #[test]
    fn param_changes_cost_time() {
        let d = Dataset::new(64, 256.0);
        let mut e1 = SimEnv::new(NetProfile::xsede(), 7).with_phase(0.0);
        let steady = e1.run_transfer(&d, 1024.0, |_, _| Params::new(8, 4, 8));
        let mut e2 = SimEnv::new(NetProfile::xsede(), 7).with_phase(0.0);
        let thrash = e2.run_transfer(&d, 1024.0, |_, ctx| {
            // oscillate cc between 8 and 16 every chunk
            if ctx.chunk_idx % 2 == 0 {
                Params::new(8, 4, 8)
            } else {
                Params::new(16, 4, 8)
            }
        });
        assert!(
            thrash.duration_s > steady.duration_s,
            "thrash={} steady={}",
            thrash.duration_s,
            steady.duration_s
        );
        assert!(thrash.samples.iter().any(|s| s.penalty_s > 0.0));
    }

    #[test]
    fn better_params_finish_faster() {
        let d = Dataset::new(64, 256.0);
        let mut e1 = SimEnv::new(NetProfile::xsede(), 9).with_phase(0.0);
        let slow = e1.run_transfer(&d, 2048.0, |_, _| Params::DEFAULT);
        let mut e2 = SimEnv::new(NetProfile::xsede(), 9).with_phase(0.0);
        let opt = {
            let load = e2.load_now();
            e2.model.true_optimum(&d, &load).0
        };
        let fast = e2.run_transfer(&d, 2048.0, |_, _| opt);
        assert!(
            fast.duration_s * 2.0 < slow.duration_s,
            "optimized should be >2x faster: {} vs {}",
            fast.duration_s,
            slow.duration_s
        );
    }

    #[test]
    fn clock_monotone_and_samples_ordered() {
        let mut e = env();
        let d = Dataset::new(40, 128.0);
        let out = e.run_transfer(&d, 512.0, |_, _| Params::new(4, 4, 4));
        for w in out.samples.windows(2) {
            assert!(w[1].t_s > w[0].t_s);
        }
    }

    #[test]
    fn no_plan_and_empty_plan_share_fault_free_behavior() {
        use crate::faults::FaultPlan;
        let d = Dataset::new(16, 256.0);
        let mut plain = SimEnv::new(NetProfile::xsede(), 11).with_phase(0.0);
        let a = plain.run_transfer(&d, 1024.0, |_, _| Params::new(8, 4, 8));
        let mut faulted = SimEnv::new(NetProfile::xsede(), 11)
            .with_phase(0.0)
            .with_faults(FaultPlan::empty());
        let b = faulted.run_transfer(&d, 1024.0, |_, _| Params::new(8, 4, 8));
        assert_eq!(a.duration_s, b.duration_s);
        assert_eq!(a.samples.len(), b.samples.len());
    }

    #[test]
    fn degradation_slows_the_transfer() {
        use crate::faults::{FaultEvent, FaultKind, FaultPlan};
        let d = Dataset::new(64, 256.0);
        let mut clean = SimEnv::new(NetProfile::xsede(), 21).with_phase(0.0);
        let base = clean.run_transfer(&d, 1024.0, |_, _| Params::new(8, 4, 8));
        let plan = FaultPlan {
            events: vec![FaultEvent {
                kind: FaultKind::LinkDegradation,
                t_start_s: 0.0,
                duration_s: 1e9,
                magnitude: 0.8,
            }],
        };
        let mut env = SimEnv::new(NetProfile::xsede(), 21)
            .with_phase(0.0)
            .with_faults(plan);
        let out = env.run_transfer(&d, 1024.0, |_, _| Params::new(8, 4, 8));
        assert!(
            out.duration_s > 2.0 * base.duration_s,
            "80% capacity loss must slow the run: {} vs {}",
            out.duration_s,
            base.duration_s
        );
        // delivered bytes bounded by the degraded capacity
        let cap = 0.2 * NetProfile::xsede().bandwidth_mbps;
        for s in &out.samples {
            assert!(s.throughput_mbps <= cap + 1e-9, "{}", s.throughput_mbps);
        }
    }

    #[test]
    fn stall_charges_dead_time_in_infallible_path() {
        use crate::faults::{FaultEvent, FaultKind, FaultPlan};
        let d = Dataset::new(8, 128.0);
        let plan = FaultPlan {
            events: vec![FaultEvent {
                kind: FaultKind::EndpointStall,
                t_start_s: 0.0,
                duration_s: 300.0,
                magnitude: 1.0,
            }],
        };
        let mut env = SimEnv::new(NetProfile::xsede(), 5)
            .with_phase(0.0)
            .with_faults(plan);
        let (measured, duration) = env.transfer_chunk(Params::new(8, 4, 8), &d, None);
        assert!(duration > 300.0, "stall must be charged: {duration}");
        assert!(env.now_s >= 300.0);
        assert!(measured < d.total_mb() * 8.0 / 300.0);
    }

    #[test]
    fn try_transfer_chunk_fails_fast_under_stall_then_recovers() {
        use crate::faults::{FaultEvent, FaultKind, FaultPlan};
        let d = Dataset::new(8, 128.0);
        let plan = FaultPlan {
            events: vec![FaultEvent {
                kind: FaultKind::EndpointStall,
                t_start_s: 0.0,
                duration_s: 60.0,
                magnitude: 1.0,
            }],
        };
        let mut env = SimEnv::new(NetProfile::xsede(), 5)
            .with_phase(0.0)
            .with_faults(plan);
        let err = env
            .try_transfer_chunk(Params::new(8, 4, 8), &d, None)
            .unwrap_err();
        assert_eq!(err, ChunkFault::EndpointStall { resume_at_s: 60.0 });
        assert!((env.now_s - STALL_DETECT_S).abs() < 1e-9);
        // once the stall clears, the same call succeeds
        env.now_s = 61.0;
        let (measured, _) = env
            .try_transfer_chunk(Params::new(8, 4, 8), &d, None)
            .unwrap();
        assert!(measured > 0.0);
    }

    #[test]
    fn faulted_runs_are_deterministic_under_seed() {
        use crate::faults::{FaultPlan, FaultPlanConfig};
        let d = Dataset::new(64, 256.0);
        let profile = NetProfile::didclab_xsede();
        let run = || {
            let plan = FaultPlan::generate(
                &profile,
                &FaultPlanConfig::with_intensity(0.8),
                0xDEAD,
            );
            let mut env = SimEnv::new(profile.clone(), 33)
                .with_phase(0.0)
                .with_faults(plan);
            env.run_transfer(&d, 512.0, |_, _| Params::new(8, 4, 8))
        };
        let a = run();
        let b = run();
        assert_eq!(a.duration_s, b.duration_s);
        let ths_a: Vec<f64> = a.samples.iter().map(|s| s.throughput_mbps).collect();
        let ths_b: Vec<f64> = b.samples.iter().map(|s| s.throughput_mbps).collect();
        assert_eq!(ths_a, ths_b);
    }

    #[test]
    fn policy_sees_running_context() {
        let mut e = env();
        let d = Dataset::new(16, 256.0);
        let mut seen_last_th = false;
        let _ = e.run_transfer(&d, 1024.0, |_, ctx| {
            if ctx.chunk_idx > 0 {
                assert!(ctx.last_throughput.is_some());
                assert!(ctx.last_params.is_some());
                seen_last_th = true;
            } else {
                assert!(ctx.last_throughput.is_none());
            }
            Params::new(4, 2, 4)
        });
        assert!(seen_last_th);
    }
}
