//! Network/endpoint profiles — Table 1 of the paper plus the Chameleon
//! Cloud path used in the §5.4 multi-user experiment.  The `exp_table1`
//! bench prints these back as the reproduction of Table 1.

/// End-to-end path + endpoint description (the `net_args`/`node_args`
/// of Algorithm 1).
#[derive(Debug, Clone, PartialEq)]
pub struct NetProfile {
    pub name: &'static str,
    /// Bottleneck link capacity in Mbps.
    pub bandwidth_mbps: f64,
    /// Round-trip time in seconds.
    pub rtt_s: f64,
    /// Per-stream TCP buffer in MB (window cap = buf / RTT).
    pub tcp_buf_mb: f64,
    /// Endpoint disk bandwidth in MB/s (shared by all processes).
    pub disk_mbps: f64,
    /// NIC speed in Mbps.
    pub nic_mbps: f64,
    /// Cores on the transfer node; concurrency beyond this pays a
    /// scheduling penalty.
    pub cores: u32,
    /// Baseline packet-loss probability of the uncongested path.
    pub base_loss: f64,
    /// TCP maximum segment size in bytes.
    pub mss_bytes: f64,
    /// Upper bound β on each protocol parameter (§4.1.3: "many systems
    /// set upper bound on those parameters").
    pub max_param: u32,
    /// Equivalent background streams at peak / off-peak hours — the
    /// contending-transfer load `l_ctd` of Eq 1.
    pub bg_streams_peak: f64,
    pub bg_streams_offpeak: f64,
}

impl NetProfile {
    /// XSEDE: Stampede (TACC) ↔ Gordon (SDSC).  10 Gbps, 40 ms RTT,
    /// 48 MB TCP buffers, 1200 MB/s parallel filesystem (Table 1).
    pub fn xsede() -> NetProfile {
        NetProfile {
            name: "xsede",
            bandwidth_mbps: 10_000.0,
            rtt_s: 0.040,
            tcp_buf_mb: 48.0,
            disk_mbps: 1200.0 * 8.0, // MB/s -> Mbps
            nic_mbps: 10_000.0,
            cores: 16,
            base_loss: 2e-6,
            mss_bytes: 1500.0,
            max_param: 32,
            bg_streams_peak: 48.0,
            bg_streams_offpeak: 12.0,
        }
    }

    /// DIDCLAB: WS-10 ↔ Evenstar.  1 Gbps LAN, 0.2 ms RTT, 10 MB
    /// buffers, 90 MB/s disks (Table 1) — disk-bound, short-RTT regime.
    pub fn didclab() -> NetProfile {
        NetProfile {
            name: "didclab",
            bandwidth_mbps: 1_000.0,
            rtt_s: 0.0002,
            tcp_buf_mb: 10.0,
            disk_mbps: 90.0 * 8.0,
            nic_mbps: 1_000.0,
            cores: 8,
            base_loss: 1e-6,
            mss_bytes: 1500.0,
            max_param: 32,
            bg_streams_peak: 6.0,
            bg_streams_offpeak: 1.5,
        }
    }

    /// DIDCLAB ↔ XSEDE over the commodity Internet: 1 Gbps bottleneck,
    /// long RTT, busy path ("quite busy Internet connection", §5.1).
    pub fn didclab_xsede() -> NetProfile {
        NetProfile {
            name: "didclab-xsede",
            bandwidth_mbps: 1_000.0,
            rtt_s: 0.030,
            tcp_buf_mb: 10.0,
            disk_mbps: 90.0 * 8.0,
            nic_mbps: 1_000.0,
            cores: 8,
            base_loss: 5e-5,
            mss_bytes: 1500.0,
            max_param: 32,
            bg_streams_peak: 40.0,
            bg_streams_offpeak: 16.0,
        }
    }

    /// Chameleon Cloud CHI-UC ↔ TACC — the §5.4 multi-user testbed.
    pub fn chameleon() -> NetProfile {
        NetProfile {
            name: "chameleon",
            bandwidth_mbps: 10_000.0,
            rtt_s: 0.032,
            tcp_buf_mb: 32.0,
            disk_mbps: 800.0 * 8.0,
            nic_mbps: 10_000.0,
            cores: 24,
            // shared cloud WAN: noticeably lossier than the dedicated
            // XSEDE path, so per-stream rates are modest (~85 Mbps) and
            // parameter choice matters — as in the §5.4 experiment
            base_loss: 2e-5,
            mss_bytes: 1500.0,
            max_param: 32,
            bg_streams_peak: 24.0,
            bg_streams_offpeak: 8.0,
        }
    }

    /// All built-in profiles (the three §5.1 networks + Chameleon).
    pub fn all() -> Vec<NetProfile> {
        vec![
            Self::xsede(),
            Self::didclab(),
            Self::didclab_xsede(),
            Self::chameleon(),
        ]
    }

    /// Look a profile up by name.
    pub fn by_name(name: &str) -> Option<NetProfile> {
        Self::all().into_iter().find(|p| p.name == name)
    }

    /// Per-stream window cap in Mbps: buffer drained once per RTT.
    pub fn window_cap_mbps(&self) -> f64 {
        self.tcp_buf_mb * 8.0 / self.rtt_s
    }

    /// Bandwidth-delay product in MB — sizing sample transfers.
    pub fn bdp_mb(&self) -> f64 {
        self.bandwidth_mbps * self.rtt_s / 8.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_values_survive() {
        let x = NetProfile::xsede();
        assert_eq!(x.bandwidth_mbps, 10_000.0);
        assert_eq!(x.rtt_s, 0.040);
        assert_eq!(x.tcp_buf_mb, 48.0);
        let d = NetProfile::didclab();
        assert_eq!(d.bandwidth_mbps, 1_000.0);
        assert_eq!(d.rtt_s, 0.0002);
    }

    #[test]
    fn lookup_by_name() {
        assert!(NetProfile::by_name("xsede").is_some());
        assert!(NetProfile::by_name("chameleon").is_some());
        assert!(NetProfile::by_name("nope").is_none());
    }

    #[test]
    fn window_cap_exceeds_link_on_xsede() {
        // 48 MB / 40 ms = 9.6 Gbps per stream: window rarely binds, the
        // loss response is what makes parallelism matter (DESIGN.md §2).
        let x = NetProfile::xsede();
        assert!(x.window_cap_mbps() > 9_000.0);
    }

    #[test]
    fn bdp_sane() {
        let x = NetProfile::xsede();
        assert!((x.bdp_mb() - 50.0).abs() < 1.0); // 10G * 40ms = 50 MB
    }
}
