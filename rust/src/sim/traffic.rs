//! Background ("contending") traffic — `l_ctd` of Eq 1.
//!
//! The paper's networks are shared: achievable throughput depends on
//! external load, which changes diurnally (peak vs off-peak hours,
//! §5.1) and stochastically while a long transfer runs.  We model the
//! equivalent number of background TCP streams at the bottleneck as
//!
//! `bg(t) = diurnal(t) · (1 + OU(t)) + burst(t)`
//!
//! where `diurnal` interpolates between the profile's off-peak and peak
//! stream counts over a 24 h cycle, `OU` is mean-reverting noise, and
//! `burst` is an occasional Poisson-arriving, exponentially-decaying
//! load spike (a contending bulk transfer coming and going).

use crate::sim::profile::NetProfile;
use crate::util::rng::Rng;

/// Snapshot of external load at some instant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LoadState {
    /// Equivalent background streams at the bottleneck.
    pub bg_streams: f64,
    /// Normalized intensity in [0, 1]: 0 = idle path, 1 = heaviest
    /// load the process generates.  Offline analysis buckets on this.
    pub intensity: f64,
    /// Whether the diurnal phase counts as peak hours.
    pub peak: bool,
}

impl LoadState {
    /// Bucket the intensity into one of `n` load-intensity tags (the
    /// per-surface `I_s` of Algorithm 1).
    pub fn bucket(&self, n: usize) -> usize {
        assert!(n > 0);
        ((self.intensity * n as f64) as usize).min(n - 1)
    }
}

/// Stateful stochastic background-traffic process.
#[derive(Debug, Clone)]
pub struct TrafficProcess {
    peak_streams: f64,
    off_streams: f64,
    /// OU state (relative, mean 0).
    ou: f64,
    /// OU mean-reversion rate (1/s) and stationary std.
    ou_theta: f64,
    ou_sigma: f64,
    /// current burst load (streams) and its decay rate
    burst: f64,
    burst_decay: f64,
    /// expected bursts per hour
    burst_rate_hr: f64,
    rng: Rng,
    /// start-of-day offset in seconds (randomized per run)
    phase_s: f64,
    last_t: f64,
}

/// Peak hours: 08:00–20:00 local, with smooth shoulders.
fn diurnal_weight(tod_s: f64) -> f64 {
    let h = tod_s / 3600.0;
    // smooth bump centred on 14:00, width ~6h
    let x = (h - 14.0) / 6.0;
    (-x * x).exp()
}

impl TrafficProcess {
    pub fn new(profile: &NetProfile, seed: u64) -> TrafficProcess {
        let mut rng = Rng::new(seed ^ 0x7261666669636b);
        let phase_s = rng.uniform(0.0, 86_400.0);
        TrafficProcess {
            peak_streams: profile.bg_streams_peak,
            off_streams: profile.bg_streams_offpeak,
            ou: 0.0,
            ou_theta: 1.0 / 600.0, // ~10 min correlation time
            ou_sigma: 0.25,
            burst: 0.0,
            burst_decay: 1.0 / 900.0, // ~15 min bursts
            burst_rate_hr: 0.5,
            rng,
            phase_s,
            last_t: 0.0,
        }
    }

    /// Fix the diurnal phase (tests and peak/off-peak experiments).
    pub fn with_phase(mut self, phase_s: f64) -> TrafficProcess {
        self.phase_s = phase_s;
        self
    }

    /// Deterministic diurnal mean at absolute time `t` seconds.
    pub fn diurnal_mean(&self, t: f64) -> f64 {
        let tod = (t + self.phase_s) % 86_400.0;
        let w = diurnal_weight(tod);
        self.off_streams + (self.peak_streams - self.off_streams) * w
    }

    /// Advance the process to time `t` (seconds, monotone) and return
    /// the load.  Steps the OU/burst dynamics by `t - last_t`.
    pub fn at(&mut self, t: f64) -> LoadState {
        let dt = (t - self.last_t).max(0.0);
        self.last_t = t;

        // OU step (exact discretization)
        if dt > 0.0 {
            let a = (-self.ou_theta * dt).exp();
            let var = self.ou_sigma * self.ou_sigma * (1.0 - a * a);
            self.ou = self.ou * a + self.rng.normal() * var.sqrt();

            // Poisson burst arrivals over dt
            let expected = self.burst_rate_hr * dt / 3600.0;
            let arrivals = self.rng.poisson(expected);
            for _ in 0..arrivals {
                self.burst += self.rng.uniform(0.3, 1.0) * self.peak_streams;
            }
            self.burst *= (-self.burst_decay * dt).exp();
        }

        let mean = self.diurnal_mean(t);
        let bg = (mean * (1.0 + self.ou) + self.burst).max(0.0);
        let max_bg = self.peak_streams * 2.5; // normalization ceiling
        let tod = (t + self.phase_s) % 86_400.0;
        LoadState {
            bg_streams: bg,
            intensity: (bg / max_bg).min(1.0),
            peak: (8.0..20.0).contains(&(tod / 3600.0)),
        }
    }

    /// A fixed load state at a given intensity (for controlled
    /// experiments and offline grid probes).
    pub fn fixed(profile: &NetProfile, intensity: f64) -> LoadState {
        let max_bg = profile.bg_streams_peak * 2.5;
        LoadState {
            bg_streams: intensity * max_bg,
            intensity,
            peak: intensity > 0.4,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xsede() -> NetProfile {
        NetProfile::xsede()
    }

    #[test]
    fn diurnal_peaks_in_afternoon() {
        let p = xsede();
        let tp = TrafficProcess::new(&p, 1).with_phase(0.0);
        let night = tp.diurnal_mean(3.0 * 3600.0);
        let noon = tp.diurnal_mean(14.0 * 3600.0);
        assert!(noon > night * 1.5, "noon={noon} night={night}");
        assert!((noon - p.bg_streams_peak).abs() < 1e-6);
    }

    #[test]
    fn load_nonnegative_and_bounded_intensity() {
        let p = xsede();
        let mut tp = TrafficProcess::new(&p, 7);
        for i in 0..2_000 {
            let l = tp.at(i as f64 * 30.0);
            assert!(l.bg_streams >= 0.0);
            assert!((0.0..=1.0).contains(&l.intensity));
        }
    }

    #[test]
    fn deterministic_under_seed() {
        let p = xsede();
        let mut a = TrafficProcess::new(&p, 42);
        let mut b = TrafficProcess::new(&p, 42);
        for i in 0..100 {
            assert_eq!(a.at(i as f64), b.at(i as f64));
        }
    }

    #[test]
    fn bursts_occur_eventually() {
        let p = xsede();
        let mut tp = TrafficProcess::new(&p, 3).with_phase(0.0);
        // sample 3 days at night; bursts should push load above the
        // diurnal mean at least sometimes
        let mut above = 0;
        for i in 0..8_640 {
            let t = i as f64 * 30.0;
            let l = tp.at(t);
            if l.bg_streams > tp.diurnal_mean(t) * 1.5 {
                above += 1;
            }
        }
        assert!(above > 0, "no bursts in 3 simulated days");
    }

    #[test]
    fn fixed_load_buckets() {
        let p = xsede();
        let l = TrafficProcess::fixed(&p, 0.9);
        assert_eq!(l.bucket(5), 4);
        let l0 = TrafficProcess::fixed(&p, 0.0);
        assert_eq!(l0.bucket(5), 0);
        let lmax = TrafficProcess::fixed(&p, 1.0);
        assert_eq!(lmax.bucket(5), 4);
    }

    #[test]
    fn peak_flag_follows_time_of_day() {
        let p = xsede();
        let mut tp = TrafficProcess::new(&p, 5).with_phase(0.0);
        assert!(!tp.at(3.0 * 3600.0).peak);
        assert!(tp.at(14.0 * 3600.0 + 1.0).peak);
    }
}
