//! Multi-user contention simulation — the §5.4 fairness experiment
//! substrate (Chameleon CHI-UC ↔ TACC, four users running the same
//! optimization technique simultaneously).
//!
//! Tick-based: every tick the simulator collects each active user's
//! protocol parameters, derives per-stream rate from the *joint*
//! equilibrium loss, water-fills the bottleneck proportionally to
//! stream counts ([`crate::sim::link::share_bottleneck`]), applies each
//! user's end-system and dataset factors, and credits the transferred
//! bytes.  User policies observe their own measured throughput once per
//! decision period — exactly the feedback loop the paper describes
//! ("individual ASM instances can detect performance drop and start
//! recalculating the parameters").

use crate::faults::{FaultEngine, FaultPlan, FaultState};
use crate::sim::dataset::Dataset;
use crate::sim::link::{share_bottleneck_under_fault, LinkDemand};
use crate::sim::profile::NetProfile;
use crate::sim::tcp;
use crate::sim::traffic::TrafficProcess;
use crate::sim::transfer::ThroughputModel;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::Params;

/// Feedback handed to a user's policy at each decision epoch.
#[derive(Debug, Clone, Copy)]
pub struct UserCtx {
    pub user_id: usize,
    pub t_s: f64,
    /// measured throughput (Mbps) over the last decision period
    pub last_throughput: Option<f64>,
    pub current_params: Params,
    pub decision_idx: usize,
}

/// A per-user parameter policy.
pub trait UserPolicy {
    /// Called once per decision period; returns the params to use next.
    fn decide(&mut self, ctx: &UserCtx) -> Params;
    fn name(&self) -> &str {
        "policy"
    }
}

impl<F: FnMut(&UserCtx) -> Params> UserPolicy for F {
    fn decide(&mut self, ctx: &UserCtx) -> Params {
        self(ctx)
    }
}

/// Result for one user.
#[derive(Debug, Clone)]
pub struct UserOutcome {
    pub user_id: usize,
    /// (t, Mbps) series at tick resolution
    pub series: Vec<(f64, f64)>,
    pub mean_throughput_mbps: f64,
    pub transferred_mb: f64,
}

/// FNV-1a over the exact bit patterns of a run's full output — every
/// per-tick series point, mean and byte total of every user.  The
/// equality witness the parallel experiment fan-out compares against
/// serial (`tests/prop_fig9_parallel.rs`, `benches/exp_fig9_multiuser`):
/// a single reordered f64 operation anywhere in a cell changes it.
pub fn outcomes_digest(outs: &[UserOutcome]) -> u64 {
    struct Fnv(u64);
    impl Fnv {
        fn u(&mut self, x: u64) {
            for byte in x.to_le_bytes() {
                self.0 ^= byte as u64;
                self.0 = self.0.wrapping_mul(0x0000_0100_0000_01B3);
            }
        }
        fn f(&mut self, v: f64) {
            self.u(v.to_bits());
        }
    }
    let mut h = Fnv(0xCBF2_9CE4_8422_2325);
    h.u(outs.len() as u64);
    for u in outs {
        h.u(u.user_id as u64);
        h.u(u.series.len() as u64);
        for &(t, th) in &u.series {
            h.f(t);
            h.f(th);
        }
        h.f(u.mean_throughput_mbps);
        h.f(u.transferred_mb);
    }
    h.0
}

/// Multi-user shared-bottleneck simulation.
pub struct MultiUserSim {
    pub profile: NetProfile,
    model: ThroughputModel,
    traffic: TrafficProcess,
    pub tick_s: f64,
    pub decision_period_s: f64,
    rng: Rng,
    /// Optional shared-bottleneck fault schedule (None = benign).
    faults: Option<FaultEngine>,
}

impl MultiUserSim {
    pub fn new(profile: NetProfile, seed: u64) -> MultiUserSim {
        let traffic = TrafficProcess::new(&profile, seed).with_phase(0.0);
        MultiUserSim {
            model: ThroughputModel::new(profile.clone()),
            profile,
            traffic,
            tick_s: 1.0,
            decision_period_s: 20.0,
            rng: Rng::new(seed ^ 0x6d756c7469),
            faults: None,
        }
    }

    /// Inject a fault schedule shared by every user (they contend on
    /// the same bottleneck and endpoint).
    pub fn with_faults(mut self, plan: FaultPlan) -> MultiUserSim {
        self.faults = Some(FaultEngine::new(plan));
        self
    }

    /// Per-user raw stream demand at the current loss (hard caps only;
    /// the soft efficiency factors are applied to the allocation so the
    /// decomposition mirrors `ThroughputModel::steady` exactly).
    fn user_demand(&self, params: Params, lambda: f64, fault: &FaultState) -> f64 {
        let p = &self.profile;
        let s = params.total_streams() as f64;
        let r = tcp::stream_rate_under_fault(p, lambda, fault);
        (s * r).min(p.disk_mbps).min(p.nic_mbps)
    }

    /// Soft efficiency factors on an allocation (steady() steps 4-5).
    fn user_efficiency(&self, params: Params, total_streams: f64) -> f64 {
        let p = &self.profile;
        let s = params.total_streams() as f64;
        let mut eff = self.model.thrash_factor(total_streams);
        eff *= self.model.sys_factor(s);
        eff *= self.model.overload_factor(total_streams);
        if params.cc > p.cores {
            eff *= (p.cores as f64 / params.cc as f64).powf(0.4);
        }
        eff
    }

    /// Dataset-dependent goodput factor (control channel + fragmentation),
    /// mirroring `ThroughputModel::steady` steps (5)-(6).
    fn dataset_factor(&self, params: Params, dataset: &Dataset, alloc_mbps: f64) -> f64 {
        let p = &self.profile;
        let files_per_ch = (dataset.n_files as f64 / params.cc as f64).max(1.0);
        let ch_rate = (alloc_mbps / params.cc as f64).max(1e-9);
        let data_t = dataset.avg_file_mb * 8.0 / ch_rate;
        let pp_eff = (params.pp as f64).min(files_per_ch).max(1.0);
        let ack_t = p.rtt_s / pp_eff + 0.001 * params.pp as f64 * p.rtt_s;
        let ctrl = data_t / (data_t + ack_t);
        let frag =
            dataset.avg_file_mb / (dataset.avg_file_mb + (params.p as f64 - 1.0) * 0.5);
        ctrl * frag
    }

    /// Run `duration_s` of contention with one policy and dataset per
    /// user.  All users transfer continuously (datasets are treated as
    /// unbounded pools, as in the paper's fixed-duration runs).
    pub fn run(
        &mut self,
        policies: &mut [Box<dyn UserPolicy>],
        datasets: &[Dataset],
        duration_s: f64,
    ) -> Vec<UserOutcome> {
        let n = policies.len();
        assert_eq!(n, datasets.len());
        let mut params: Vec<Params> = vec![Params::DEFAULT; n];
        let mut series: Vec<Vec<(f64, f64)>> = vec![Vec::new(); n];
        let mut moved_mb = vec![0.0f64; n];
        let mut window_mb = vec![0.0f64; n];
        let mut decision_idx = vec![0usize; n];
        // dead time remaining per user (param-change penalties)
        let mut stall_s = vec![0.0f64; n];

        // initial decisions
        for (i, pol) in policies.iter_mut().enumerate() {
            let ctx = UserCtx {
                user_id: i,
                t_s: 0.0,
                last_throughput: None,
                current_params: params[i],
                decision_idx: 0,
            };
            params[i] = pol.decide(&ctx).clamp(self.profile.max_param);
            decision_idx[i] = 1;
        }

        let ticks = (duration_s / self.tick_s).ceil() as usize;
        let decision_ticks = (self.decision_period_s / self.tick_s).round() as usize;

        for tick in 0..ticks {
            let t = tick as f64 * self.tick_s;
            let load = self.traffic.at(t);
            let fs = self
                .faults
                .as_ref()
                .map(|f| f.state_at(t))
                .unwrap_or_default();

            // joint equilibrium loss across every user's streams + bg
            // (surge streams contend for loss like any other traffic)
            let total_streams: f64 = params
                .iter()
                .map(|p| p.total_streams() as f64)
                .sum::<f64>()
                + load.bg_streams
                + fs.extra_bg_streams;
            let lambda = self.model.pressure_loss(total_streams);

            let demands: Vec<LinkDemand> = (0..n)
                .map(|i| LinkDemand {
                    streams: params[i].total_streams() as f64,
                    demand_mbps: self.user_demand(params[i], lambda, &fs),
                })
                .collect();
            // raw bg here: the fault hook adds the surge streams itself
            let alloc = share_bottleneck_under_fault(
                self.profile.bandwidth_mbps,
                &demands,
                load.bg_streams,
                &fs,
            );
            let endpoint_stalled = fs.is_stalled_at(t);

            for i in 0..n {
                let mut th = alloc[i]
                    * self.user_efficiency(params[i], total_streams)
                    * self.dataset_factor(params[i], &datasets[i], alloc[i]);
                // measurement noise at tick granularity
                th *= self.rng.lognormal(0.0, 0.03);
                // a stalled endpoint serves nobody this tick
                if endpoint_stalled {
                    th = 0.0;
                }
                // stalled users (param-change dead time) move nothing
                if stall_s[i] > 0.0 {
                    let stalled = stall_s[i].min(self.tick_s);
                    stall_s[i] -= stalled;
                    th *= 1.0 - stalled / self.tick_s;
                }
                series[i].push((t, th));
                let mb = th / 8.0 * self.tick_s;
                moved_mb[i] += mb;
                window_mb[i] += mb;
            }

            // decision epochs, staggered per user (the §5.4 first-prober
            // asymmetry: users do not probe in lockstep)
            for i in 0..n {
                let offset = i * decision_ticks / n.max(1);
                if (tick + 1) % decision_ticks == offset % decision_ticks {
                    let measured =
                        window_mb[i] * 8.0 / (decision_ticks as f64 * self.tick_s);
                    let ctx = UserCtx {
                        user_id: i,
                        t_s: t,
                        last_throughput: Some(measured),
                        current_params: params[i],
                        decision_idx: decision_idx[i],
                    };
                    let next = policies[i].decide(&ctx).clamp(self.profile.max_param);
                    if next != params[i] {
                        stall_s[i] += self.model.param_change_penalty_s(params[i], next);
                        params[i] = next;
                    }
                    decision_idx[i] += 1;
                    window_mb[i] = 0.0;
                }
            }
        }

        (0..n)
            .map(|i| {
                let ths: Vec<f64> = series[i].iter().map(|&(_, th)| th).collect();
                UserOutcome {
                    user_id: i,
                    mean_throughput_mbps: stats::mean(&ths),
                    series: std::mem::take(&mut series[i]),
                    transferred_mb: moved_mb[i],
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dataset() -> Dataset {
        Dataset::new(256, 512.0)
    }

    fn static_policy(params: Params) -> Box<dyn UserPolicy> {
        Box::new(move |_: &UserCtx| params)
    }

    #[test]
    fn aggregate_never_exceeds_link() {
        let mut sim = MultiUserSim::new(NetProfile::chameleon(), 1);
        let mut pols: Vec<Box<dyn UserPolicy>> = (0..4)
            .map(|_| static_policy(Params::new(8, 4, 8)))
            .collect();
        let ds = vec![dataset(); 4];
        let out = sim.run(&mut pols, &ds, 120.0);
        let cap = sim.profile.bandwidth_mbps;
        let nticks = out[0].series.len();
        for t in 0..nticks {
            let total: f64 = out.iter().map(|u| u.series[t].1).sum();
            assert!(total <= cap * 1.15, "tick {t}: total={total}"); // noise slack
        }
    }

    #[test]
    fn identical_users_get_fair_shares() {
        let mut sim = MultiUserSim::new(NetProfile::chameleon(), 3);
        let mut pols: Vec<Box<dyn UserPolicy>> = (0..4)
            .map(|_| static_policy(Params::new(8, 4, 8)))
            .collect();
        let ds = vec![dataset(); 4];
        let out = sim.run(&mut pols, &ds, 300.0);
        let means: Vec<f64> = out.iter().map(|u| u.mean_throughput_mbps).collect();
        let jain = stats::jain_index(&means);
        assert!(jain > 0.98, "jain={jain} means={means:?}");
    }

    #[test]
    fn more_streams_grab_more_share() {
        let mut sim = MultiUserSim::new(NetProfile::chameleon(), 5);
        let mut pols: Vec<Box<dyn UserPolicy>> = vec![
            static_policy(Params::new(16, 4, 8)),
            static_policy(Params::new(2, 1, 8)),
        ];
        let ds = vec![dataset(); 2];
        let out = sim.run(&mut pols, &ds, 200.0);
        assert!(
            out[0].mean_throughput_mbps > 2.0 * out[1].mean_throughput_mbps,
            "{} vs {}",
            out[0].mean_throughput_mbps,
            out[1].mean_throughput_mbps
        );
    }

    #[test]
    fn param_changes_stall_users() {
        let mut sim = MultiUserSim::new(NetProfile::chameleon(), 7);
        // policy that re-shapes cc/p every decision while keeping the
        // same total stream count (so only the switch penalty differs)
        struct Thrash(bool);
        impl UserPolicy for Thrash {
            fn decide(&mut self, _ctx: &UserCtx) -> Params {
                self.0 = !self.0;
                if self.0 {
                    Params::new(8, 4, 8)
                } else {
                    Params::new(4, 8, 8)
                }
            }
        }
        let mut pols: Vec<Box<dyn UserPolicy>> =
            vec![Box::new(Thrash(false)), static_policy(Params::new(8, 4, 8))];
        let ds = vec![dataset(); 2];
        let out = sim.run(&mut pols, &ds, 300.0);
        // the thrasher pays stall time the steady user doesn't
        assert!(out[0].transferred_mb < out[1].transferred_mb);
    }

    #[test]
    fn shared_degradation_cuts_every_user() {
        use crate::faults::{FaultEvent, FaultKind, FaultPlan};
        let plan = FaultPlan {
            events: vec![FaultEvent {
                kind: FaultKind::LinkDegradation,
                t_start_s: 0.0,
                duration_s: 1e9,
                magnitude: 0.8,
            }],
        };
        let ds = vec![dataset(); 4];
        let run = |plan: Option<FaultPlan>| {
            let mut sim = MultiUserSim::new(NetProfile::chameleon(), 11);
            if let Some(p) = plan {
                sim = sim.with_faults(p);
            }
            let mut pols: Vec<Box<dyn UserPolicy>> = (0..4)
                .map(|_| static_policy(Params::new(8, 4, 8)))
                .collect();
            sim.run(&mut pols, &ds, 120.0)
        };
        let clean = run(None);
        let faulted = run(Some(plan));
        for (c, f) in clean.iter().zip(&faulted) {
            assert!(
                f.mean_throughput_mbps < 0.5 * c.mean_throughput_mbps,
                "user {}: {} vs {}",
                c.user_id,
                f.mean_throughput_mbps,
                c.mean_throughput_mbps
            );
        }
    }

    #[test]
    fn endpoint_stall_freezes_all_users() {
        use crate::faults::{FaultEvent, FaultKind, FaultPlan};
        let plan = FaultPlan {
            events: vec![FaultEvent {
                kind: FaultKind::EndpointStall,
                t_start_s: 30.0,
                duration_s: 20.0,
                magnitude: 1.0,
            }],
        };
        let mut sim = MultiUserSim::new(NetProfile::chameleon(), 13).with_faults(plan);
        let mut pols: Vec<Box<dyn UserPolicy>> = (0..2)
            .map(|_| static_policy(Params::new(8, 4, 8)))
            .collect();
        let ds = vec![dataset(); 2];
        let out = sim.run(&mut pols, &ds, 120.0);
        for u in &out {
            for &(t, th) in &u.series {
                if (30.0..50.0).contains(&t) {
                    assert_eq!(th, 0.0, "user {} at t={t}", u.user_id);
                } else if !(29.0..51.0).contains(&t) {
                    assert!(th > 0.0, "user {} at t={t}", u.user_id);
                }
            }
        }
    }

    #[test]
    fn default_params_underutilize() {
        let mut sim = MultiUserSim::new(NetProfile::chameleon(), 9);
        let mut pols: Vec<Box<dyn UserPolicy>> =
            (0..4).map(|_| static_policy(Params::DEFAULT)).collect();
        let ds = vec![dataset(); 4];
        let out = sim.run(&mut pols, &ds, 120.0);
        let total: f64 = out.iter().map(|u| u.mean_throughput_mbps).sum();
        assert!(
            total < 0.4 * sim.profile.bandwidth_mbps,
            "default should underutilize: {total}"
        );
    }
}
