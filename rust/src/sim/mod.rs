//! The testbed substrate: a mechanistic wide-area data-transfer
//! simulator standing in for the paper's XSEDE / DIDCLAB / Chameleon
//! environments (DESIGN.md §2 documents the substitution).
//!
//! The simulator reproduces the *mechanisms* that make the paper's
//! throughput function `th = f(e_s, e_d, b, rtt, f_avg, n, cc, p, pp,
//! l_ctd)` (Eq 1) look the way it does:
//!
//! * per-stream TCP throughput capped by window (buffer/RTT) and by the
//!   Mathis loss response `MSS / (RTT · √loss)`;
//! * congestion loss growing with total offered load on the bottleneck;
//! * TCP-fair sharing against background streams (`l_ctd`);
//! * control-channel round trips per file, amortized by pipelining;
//! * parallelism fragmentation overhead on small files;
//! * end-system caps (disk, NIC, cores) and per-process overhead;
//! * slow-start ramp + process startup cost when parameters change
//!   mid-transfer (the paper's Issue 2/3);
//! * a diurnal peak/off-peak background-traffic process with
//!   Ornstein–Uhlenbeck noise and Poisson bursts.

pub mod dataset;
pub mod engine;
pub mod link;
pub mod multiuser;
pub mod profile;
pub mod tcp;
pub mod traffic;
pub mod transfer;

pub use dataset::{Dataset, FileSizeClass};
pub use engine::{ChunkFault, SimEnv, TransferOutcome, STALL_DETECT_S};
pub use multiuser::{MultiUserSim, UserOutcome};
pub use profile::NetProfile;
pub use traffic::{LoadState, TrafficProcess};
pub use transfer::ThroughputModel;
