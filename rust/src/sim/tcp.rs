//! Per-stream TCP throughput model.
//!
//! Steady state: a stream is limited by the slower of
//! * the window cap `buf / RTT` (socket buffer drained once per RTT);
//! * the Mathis et al. loss response `(MSS / RTT) · (C / √loss)` —
//!   the reason opening `cc × p` streams helps on lossy long-RTT paths
//!   and the reason *excessive* streams hurt once they induce loss (§2).
//!
//! Transient: newly-opened streams spend `log2(W_ss / W_init)` RTTs in
//! slow start; we charge that as an equivalent dead time, which is what
//! makes mid-transfer parameter changes expensive (the paper's Issue 2
//! and the "changing parameters in real-time is expensive" note, §4.2).

use crate::sim::profile::NetProfile;

/// Mathis constant C = sqrt(3/2) for periodic-loss TCP Reno.
const MATHIS_C: f64 = 1.224744871391589;

/// Steady-state per-stream rate in Mbps under loss probability `loss`.
pub fn stream_rate_mbps(profile: &NetProfile, loss: f64) -> f64 {
    let window_cap = profile.window_cap_mbps();
    let loss = loss.max(1e-12);
    // MSS bits per RTT, scaled by Mathis loss response
    let mathis = (profile.mss_bytes * 8.0 / 1e6) / profile.rtt_s * MATHIS_C / loss.sqrt();
    window_cap.min(mathis).min(profile.bandwidth_mbps)
}

/// Effective loss probability when `offered_mbps` of demand meets a
/// bottleneck of `capacity_mbps`: base path loss plus a congestion term
/// that grows quadratically once utilization exceeds ~92% (queue
/// build-up then tail drop).  This is the feedback that gives the
/// throughput surfaces their interior maxima.
pub fn congestion_loss(base_loss: f64, offered_mbps: f64, capacity_mbps: f64) -> f64 {
    let u = offered_mbps / capacity_mbps;
    let knee = 0.92;
    if u <= knee {
        base_loss
    } else {
        let over = u - knee;
        // capped at 0.5: loss is a probability, and past ~50% TCP is
        // effectively stalled anyway
        (base_loss + 2e-5 * over * over / (knee * knee)).min(0.5)
    }
}

/// Fault-injection hook: per-stream rate when a [`FaultState`] is
/// active.  The profile is degraded first (capacity and window cap
/// shrink, RTT inflates) and the fault's extra loss is added to the
/// congestion loss, so every downstream consumer sees a consistent
/// picture of the degraded path.  With a clear state this is exactly
/// [`stream_rate_mbps`].
pub fn stream_rate_under_fault(
    profile: &NetProfile,
    loss: f64,
    fault: &crate::faults::FaultState,
) -> f64 {
    if fault.is_clear() {
        return stream_rate_mbps(profile, loss);
    }
    let degraded = fault.degrade(profile);
    stream_rate_mbps(&degraded, loss + fault.extra_loss)
}

/// Slow-start dead time (seconds) charged when `new_streams` streams
/// are (re)opened: ~`log2(W_ss / MSS)` RTTs at roughly half rate, plus
/// a flat per-process setup cost charged by the caller.
pub fn slow_start_penalty_s(profile: &NetProfile, per_stream_rate_mbps: f64) -> f64 {
    let w_ss_bytes = per_stream_rate_mbps * 1e6 / 8.0 * profile.rtt_s; // target window
    let ratio = (w_ss_bytes / profile.mss_bytes).max(2.0);
    // half the ramp is "lost" relative to steady state
    0.5 * ratio.log2() * profile.rtt_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_cap_binds_at_tiny_loss() {
        let p = NetProfile::didclab(); // 10 MB buf / 0.2 ms = huge cap
        let r = stream_rate_mbps(&p, 1e-12);
        assert!((r - p.bandwidth_mbps).abs() < 1e-9); // clamped to link
    }

    #[test]
    fn mathis_binds_at_high_loss() {
        let p = NetProfile::xsede();
        let lossy = stream_rate_mbps(&p, 1e-3);
        let clean = stream_rate_mbps(&p, 1e-6);
        assert!(lossy < clean);
        // 1500B * 8 / 40ms = 0.3 Mbps base; /sqrt(1e-3) ~ 38.7 * C
        assert!((lossy - 0.3 * MATHIS_C / (1e-3f64).sqrt()).abs() / lossy < 1e-6);
    }

    #[test]
    fn loss_flat_below_knee_grows_above() {
        let base = 1e-6;
        assert_eq!(congestion_loss(base, 500.0, 1000.0), base);
        assert_eq!(congestion_loss(base, 919.0, 1000.0), base);
        let l1 = congestion_loss(base, 1000.0, 1000.0);
        let l2 = congestion_loss(base, 1200.0, 1000.0);
        assert!(l1 > base && l2 > l1);
    }

    #[test]
    fn slow_start_penalty_scales_with_rtt() {
        let x = NetProfile::xsede(); // 40 ms
        let d = NetProfile::didclab(); // 0.2 ms
        let px = slow_start_penalty_s(&x, 300.0);
        let pd = slow_start_penalty_s(&d, 300.0);
        assert!(px > pd * 50.0, "px={px} pd={pd}");
        assert!(px < 1.0, "penalty should be sub-second: {px}");
    }

    #[test]
    fn stream_rate_monotone_in_loss() {
        let p = NetProfile::didclab_xsede();
        let mut prev = f64::INFINITY;
        for &l in &[1e-6, 1e-5, 1e-4, 1e-3, 1e-2] {
            let r = stream_rate_mbps(&p, l);
            assert!(r <= prev + 1e-12);
            prev = r;
        }
    }

    #[test]
    fn fault_hook_is_identity_when_clear() {
        use crate::faults::FaultState;
        let p = NetProfile::xsede();
        for &l in &[1e-6, 1e-4, 1e-2] {
            assert_eq!(
                stream_rate_under_fault(&p, l, &FaultState::clear()),
                stream_rate_mbps(&p, l)
            );
        }
    }

    #[test]
    fn fault_hook_degrades_rate() {
        use crate::faults::FaultState;
        let p = NetProfile::xsede();
        let healthy = stream_rate_mbps(&p, 1e-5);
        for fault in [
            FaultState {
                extra_loss: 1e-3,
                ..FaultState::clear()
            },
            FaultState {
                rtt_factor: 4.0,
                ..FaultState::clear()
            },
            FaultState {
                capacity_factor: 0.01,
                ..FaultState::clear()
            },
        ] {
            let r = stream_rate_under_fault(&p, 1e-5, &fault);
            assert!(r < healthy, "{fault:?}: {r} vs {healthy}");
        }
    }
}
