//! Bottleneck-link allocation across concurrent jobs — the multi-user
//! fairness substrate (§5.4).  TCP divides a bottleneck roughly in
//! proportion to stream counts; jobs whose end systems can't absorb
//! their share leave the surplus to others (max-min style water-fill).

/// One job's demand on the bottleneck.
#[derive(Debug, Clone, Copy)]
pub struct LinkDemand {
    /// TCP streams the job has open (its share weight).
    pub streams: f64,
    /// The most it can use (stream rate × streams, end-system caps...).
    pub demand_mbps: f64,
}

/// Allocate `capacity_mbps` across jobs proportionally to stream count,
/// with `bg_streams` phantom streams modelling external traffic that
/// consumes its own share.  Water-fills: capped jobs return surplus to
/// the uncapped pool.  Returns per-job allocations (Σ ≤ capacity).
pub fn share_bottleneck(
    capacity_mbps: f64,
    demands: &[LinkDemand],
    bg_streams: f64,
) -> Vec<f64> {
    let n = demands.len();
    let mut alloc = vec![0.0; n];
    if n == 0 {
        return alloc;
    }
    let mut active: Vec<usize> = (0..n).collect();
    // background claims its proportional share up front
    let total_streams: f64 =
        demands.iter().map(|d| d.streams).sum::<f64>() + bg_streams;
    let mut pool = capacity_mbps * (1.0 - bg_streams / total_streams.max(1e-9));

    // iterative water-fill: settle jobs whose demand is below their
    // proportional share, redistribute the remainder
    for _ in 0..n + 1 {
        if active.is_empty() || pool <= 1e-12 {
            break;
        }
        let w: f64 = active.iter().map(|&i| demands[i].streams).sum();
        if w <= 1e-12 {
            break;
        }
        let mut newly_capped = Vec::new();
        for &i in &active {
            let fair = pool * demands[i].streams / w;
            if demands[i].demand_mbps <= fair {
                alloc[i] = demands[i].demand_mbps;
                newly_capped.push(i);
            }
        }
        if newly_capped.is_empty() {
            // everyone is bottleneck-limited: take the fair split
            for &i in &active {
                alloc[i] = pool * demands[i].streams / w;
            }
            break;
        }
        let used: f64 = newly_capped.iter().map(|&i| alloc[i]).sum();
        pool -= used;
        active.retain(|i| !newly_capped.contains(i));
    }
    alloc
}

/// Fault-injection hook: water-fill over a degraded bottleneck.  The
/// capacity factor shrinks the pool and surge streams contend for
/// their proportional share alongside the diurnal background.  With a
/// clear state this is exactly [`share_bottleneck`].
pub fn share_bottleneck_under_fault(
    capacity_mbps: f64,
    demands: &[LinkDemand],
    bg_streams: f64,
    fault: &crate::faults::FaultState,
) -> Vec<f64> {
    share_bottleneck(
        capacity_mbps * fault.capacity_factor,
        demands,
        bg_streams + fault.extra_bg_streams,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn d(streams: f64, demand: f64) -> LinkDemand {
        LinkDemand {
            streams,
            demand_mbps: demand,
        }
    }

    #[test]
    fn equal_jobs_split_equally() {
        let a = share_bottleneck(1000.0, &[d(8.0, 900.0), d(8.0, 900.0)], 0.0);
        assert!((a[0] - 500.0).abs() < 1e-6);
        assert!((a[1] - 500.0).abs() < 1e-6);
    }

    #[test]
    fn share_proportional_to_streams() {
        let a = share_bottleneck(900.0, &[d(1.0, 1e9), d(2.0, 1e9)], 0.0);
        assert!((a[0] - 300.0).abs() < 1e-6, "{a:?}");
        assert!((a[1] - 600.0).abs() < 1e-6);
    }

    #[test]
    fn capped_job_returns_surplus() {
        let a = share_bottleneck(1000.0, &[d(8.0, 100.0), d(8.0, 1e9)], 0.0);
        assert!((a[0] - 100.0).abs() < 1e-6);
        assert!((a[1] - 900.0).abs() < 1e-6);
    }

    #[test]
    fn background_takes_its_share() {
        let a = share_bottleneck(1000.0, &[d(10.0, 1e9)], 10.0);
        assert!((a[0] - 500.0).abs() < 1e-6, "{a:?}");
    }

    #[test]
    fn never_oversubscribes() {
        let a = share_bottleneck(
            1000.0,
            &[d(4.0, 800.0), d(6.0, 700.0), d(2.0, 50.0)],
            5.0,
        );
        assert!(a.iter().sum::<f64>() <= 1000.0 + 1e-9, "{a:?}");
        for (i, &x) in a.iter().enumerate() {
            assert!(x >= 0.0 && x <= [800.0, 700.0, 50.0][i] + 1e-9);
        }
    }

    #[test]
    fn empty_is_empty() {
        assert!(share_bottleneck(1000.0, &[], 5.0).is_empty());
    }

    #[test]
    fn zero_capacity_allocates_zero() {
        let a = share_bottleneck(0.0, &[d(4.0, 100.0)], 0.0);
        assert_eq!(a[0], 0.0);
    }

    #[test]
    fn fault_hook_is_identity_when_clear() {
        use crate::faults::FaultState;
        let demands = [d(8.0, 900.0), d(8.0, 900.0)];
        let clear = share_bottleneck_under_fault(1000.0, &demands, 4.0, &FaultState::clear());
        assert_eq!(clear, share_bottleneck(1000.0, &demands, 4.0));
    }

    #[test]
    fn fault_hook_shrinks_pool_and_adds_contention() {
        use crate::faults::FaultState;
        let demands = [d(10.0, 1e9)];
        let fault = FaultState {
            capacity_factor: 0.5,
            extra_bg_streams: 10.0,
            ..FaultState::clear()
        };
        let a = share_bottleneck_under_fault(1000.0, &demands, 0.0, &fault);
        // half the pool, then a further half to the surge streams
        assert!((a[0] - 250.0).abs() < 1e-6, "{a:?}");
    }
}
