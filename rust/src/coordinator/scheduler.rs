//! Chunk planning: how much data each sample transfer and each
//! streaming chunk moves.
//!
//! Sample transfers use "a small predefined portion of the data"
//! (§4): large enough to climb out of slow start (a multiple of the
//! path BDP), small enough that the ⌈log₂ η⌉ bisection costs little.
//! Streaming chunks are sized so the monitor gets a decision roughly
//! every `target_decision_s` seconds at the expected throughput.

use crate::sim::dataset::Dataset;
use crate::sim::profile::NetProfile;

/// Sizing decisions for one transfer.
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkPlan {
    pub sample_chunk_mb: f64,
    pub stream_chunk_mb: f64,
}

/// Retry-with-exponential-backoff schedule for failed chunk attempts
/// (endpoint stalls, sample-transfer failures).  Deterministic: no
/// jitter, so identically-seeded runs recover identically.
#[derive(Debug, Clone, PartialEq)]
pub struct RetryPolicy {
    /// attempts per chunk before the transfer is declared failed
    pub max_attempts: usize,
    /// wait before the first retry
    pub base_backoff_s: f64,
    /// backoff growth per retry
    pub multiplier: f64,
    /// ceiling on any single wait
    pub max_backoff_s: f64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 5,
            base_backoff_s: 2.0,
            multiplier: 2.0,
            max_backoff_s: 60.0,
        }
    }
}

impl RetryPolicy {
    /// Backoff before retry number `attempt` (1-based: the wait after
    /// the first failure is `backoff_s(1) = base`).
    pub fn backoff_s(&self, attempt: usize) -> f64 {
        let exp = attempt.saturating_sub(1) as f64;
        (self.base_backoff_s * self.multiplier.powf(exp)).min(self.max_backoff_s)
    }

    /// Total dead time if every allowed retry is consumed.
    pub fn worst_case_backoff_s(&self) -> f64 {
        (1..self.max_attempts).map(|a| self.backoff_s(a)).sum()
    }
}

/// Scheduler configuration.
#[derive(Debug, Clone)]
pub struct SchedulerConfig {
    /// sample chunk = max(bdp_multiple × BDP, min_sample_mb)
    pub bdp_multiple: f64,
    pub min_sample_mb: f64,
    /// cap the sample fraction of the whole dataset
    pub max_sample_frac: f64,
    /// desired seconds between streaming-phase decisions
    pub target_decision_s: f64,
    /// chunk-failure retry schedule
    pub retry: RetryPolicy,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            bdp_multiple: 32.0,
            min_sample_mb: 64.0,
            max_sample_frac: 0.05,
            target_decision_s: 15.0,
            retry: RetryPolicy::default(),
        }
    }
}

/// Plan chunk sizes for a transfer.
pub fn plan_chunks(
    profile: &NetProfile,
    dataset: &Dataset,
    expected_th_mbps: f64,
    cfg: &SchedulerConfig,
) -> ChunkPlan {
    let total = dataset.total_mb();
    let sample = (cfg.bdp_multiple * profile.bdp_mb())
        .max(cfg.min_sample_mb)
        .min(total * cfg.max_sample_frac)
        .max(dataset.avg_file_mb.min(total)) // at least one file
        .min(total);
    let stream = (expected_th_mbps.max(50.0) / 8.0 * cfg.target_decision_s)
        .max(sample)
        .min(total);
    ChunkPlan {
        sample_chunk_mb: sample,
        stream_chunk_mb: stream,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_scales_with_bdp() {
        let cfg = SchedulerConfig::default();
        let big = Dataset::new(32_000, 16.0); // 512 GB of 16 MB files
        let x = plan_chunks(&NetProfile::xsede(), &big, 5_000.0, &cfg);
        let d = plan_chunks(&NetProfile::didclab(), &big, 500.0, &cfg);
        // XSEDE BDP 50 MB -> 1.6 GB samples; DIDCLAB BDP tiny -> floor
        assert!(x.sample_chunk_mb > d.sample_chunk_mb);
        assert_eq!(d.sample_chunk_mb, 64.0);
    }

    #[test]
    fn sample_capped_for_small_datasets() {
        let cfg = SchedulerConfig::default();
        let small = Dataset::new(100, 1.0); // 100 MB total
        let p = plan_chunks(&NetProfile::xsede(), &small, 1_000.0, &cfg);
        assert!(p.sample_chunk_mb <= 100.0);
        assert!(p.stream_chunk_mb <= 100.0);
    }

    #[test]
    fn stream_chunks_track_throughput() {
        let cfg = SchedulerConfig::default();
        let d = Dataset::new(10_000, 64.0);
        let slow = plan_chunks(&NetProfile::xsede(), &d, 500.0, &cfg);
        let fast = plan_chunks(&NetProfile::xsede(), &d, 8_000.0, &cfg);
        assert!(fast.stream_chunk_mb > slow.stream_chunk_mb);
        // ~15 s of data at 8 Gbps = 15 GB
        assert!((fast.stream_chunk_mb - 15_000.0).abs() < 1.0);
    }

    #[test]
    fn backoff_grows_exponentially() {
        let r = RetryPolicy::default();
        assert_eq!(r.backoff_s(1), 2.0);
        assert_eq!(r.backoff_s(2), 4.0);
        assert_eq!(r.backoff_s(3), 8.0);
        assert_eq!(r.backoff_s(4), 16.0);
        for a in 1..10 {
            assert!(r.backoff_s(a + 1) >= r.backoff_s(a));
        }
    }

    #[test]
    fn backoff_is_capped() {
        let r = RetryPolicy::default();
        assert_eq!(r.backoff_s(6), 60.0); // 2·2⁵ = 64 > cap
        assert_eq!(r.backoff_s(50), 60.0);
        let tight = RetryPolicy {
            max_backoff_s: 3.0,
            ..RetryPolicy::default()
        };
        assert_eq!(tight.backoff_s(1), 2.0);
        assert_eq!(tight.backoff_s(2), 3.0);
    }

    #[test]
    fn worst_case_sums_the_schedule() {
        let r = RetryPolicy::default();
        // 2 + 4 + 8 + 16 between 5 attempts
        assert_eq!(r.worst_case_backoff_s(), 30.0);
    }

    #[test]
    fn backoff_is_deterministic() {
        let a = RetryPolicy::default();
        let b = RetryPolicy::default();
        for attempt in 1..20 {
            assert_eq!(a.backoff_s(attempt), b.backoff_s(attempt));
        }
    }

    #[test]
    fn stream_never_below_sample() {
        let cfg = SchedulerConfig::default();
        let d = Dataset::new(4_000, 64.0);
        let p = plan_chunks(&NetProfile::xsede(), &d, 10.0, &cfg);
        assert!(p.stream_chunk_mb >= p.sample_chunk_mb);
    }
}
