//! Layer-3 coordination: the deployable transfer service.
//!
//! * [`scheduler`] — chunk sizing, sample-transfer budgeting, and the
//!   retry-with-exponential-backoff policy for faulted chunks;
//! * [`state`] — the per-transfer state machine (queued → sampling →
//!   streaming → retuning/recovering → done) with transition
//!   validation;
//! * [`metrics`] — the Eq-21 accuracy metric and report aggregation;
//! * [`fairness`] — the §3 centralized-scheduler variant (global view)
//!   next to the default distributed mode;
//! * [`orchestrator`] — the leader loop: request intake over std mpsc
//!   channels, a worker pool driving transfers through the simulator,
//!   and report collection (tokio is unavailable offline — DESIGN.md §4
//!   documents the std-thread architecture).

pub mod fairness;
pub mod metrics;
pub mod orchestrator;
pub mod scheduler;
pub mod state;

pub use metrics::{accuracy_pct, TransferReport};
pub use orchestrator::{
    Checkpoint, Orchestrator, OrchestratorConfig, RecoveryReport, TransferRequest,
};
pub use scheduler::{ChunkPlan, RetryPolicy};
pub use state::TransferState;
