//! The leader loop: accepts transfer requests, builds the right
//! optimizer for each (ASM from the knowledge base, or any §5
//! baseline), drives the chunked transfer through the simulator, and
//! emits [`TransferReport`]s.  Batch mode fans requests out to a
//! worker-thread pool over std mpsc channels.

use crate::baselines::ann_ot::{AnnOt, AnnOtModel};
use crate::baselines::api::{AsmOptimizer, NoOptimization, Optimizer, OptimizerKind};
use crate::baselines::globus::Globus;
use crate::baselines::harp::Harp;
use crate::baselines::nelder_mead::NelderMead;
use crate::baselines::single_chunk::SingleChunk;
use crate::baselines::static_ann::{StaticAnn, StaticAnnModel};
use crate::coordinator::metrics::TransferReport;
use crate::coordinator::scheduler::{plan_chunks, SchedulerConfig};
use crate::coordinator::state::TransferState;
use crate::faults::FaultPlan;
use crate::offline::cache::{CacheStats, Fingerprint, TuningCache};
use crate::offline::pipeline::KnowledgeBase;
use crate::online::controller::{DynamicTuner, TunerConfig};
use crate::sim::dataset::Dataset;
use crate::sim::engine::{ChunkFault, ChunkSample, SimEnv, TransferOutcome};
use crate::faults::FaultState;
use crate::sim::profile::NetProfile;
use crate::util::err::Result;
use crate::util::json::Value;
use crate::util::trace::Tracer;
use std::sync::{mpsc, Arc, Mutex, MutexGuard};

/// Trace fields for a [`FaultState`] snapshot.
fn fault_state_fields(s: &FaultState) -> Vec<(&'static str, Value)> {
    vec![
        ("capacity_factor", Value::Num(s.capacity_factor)),
        ("extra_loss", Value::Num(s.extra_loss)),
        ("rtt_factor", Value::Num(s.rtt_factor)),
        ("extra_bg_streams", Value::Num(s.extra_bg_streams)),
        ("stalled", Value::Bool(s.stalled_until_s.is_some())),
    ]
}

/// One transfer job.
#[derive(Debug, Clone)]
pub struct TransferRequest {
    pub id: u64,
    pub profile: NetProfile,
    pub dataset: Dataset,
    pub model: OptimizerKind,
    pub seed: u64,
    /// diurnal phase offset (seconds): pins peak vs off-peak
    pub phase_s: f64,
}

#[derive(Debug, Clone)]
pub struct OrchestratorConfig {
    pub workers: usize,
    pub scheduler: SchedulerConfig,
    pub tuner: TunerConfig,
    /// chunks transferred at sample size before switching to stream
    /// size (covers every model's probing phase)
    pub sampling_chunks: usize,
    /// capacity of the historical tuning cache; 0 (the default)
    /// disables it, keeping every run a cold start — experiments need
    /// cold-start comparability, and repeated identical requests must
    /// stay bit-identical whether run sequentially or batched
    pub cache_capacity: usize,
}

impl Default for OrchestratorConfig {
    fn default() -> Self {
        OrchestratorConfig {
            workers: 4,
            scheduler: SchedulerConfig::default(),
            tuner: TunerConfig::default(),
            sampling_chunks: 6,
            cache_capacity: 0,
        }
    }
}

/// Mid-transfer progress snapshot.  Chunk transfers are atomic in the
/// simulator, so the checkpoint sits at the last completed chunk
/// boundary; a failed attempt retries the same chunk with the same
/// remaining bytes — completed work is never re-sent.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Checkpoint {
    /// chunks completed so far (also the index of the chunk to retry)
    pub chunk_idx: usize,
    pub transferred_mb: f64,
    pub remaining_mb: f64,
}

/// A [`TransferReport`] plus the recovery trace accumulated by
/// [`Orchestrator::execute_with_faults`].
#[derive(Debug, Clone)]
pub struct RecoveryReport {
    pub report: TransferReport,
    /// failed chunk attempts that were retried
    pub retries: usize,
    /// wall clock spent waiting in exponential backoff
    pub backoff_total_s: f64,
    /// chunks that completed after at least one failed attempt
    pub resumed_chunks: usize,
    /// false when some chunk exhausted its retry budget (→ `Failed`)
    pub completed: bool,
    /// final progress snapshot (remaining_mb > 0 iff not completed)
    pub checkpoint: Checkpoint,
}

/// The transfer service.
pub struct Orchestrator {
    pub kb: Arc<KnowledgeBase>,
    pub sp_model: Arc<StaticAnnModel>,
    pub annot_model: Arc<AnnOtModel>,
    pub cfg: OrchestratorConfig,
    /// historical tuning cache (Mutex keeps the orchestrator usable
    /// from `run_batch`'s worker threads)
    cache: Mutex<TuningCache>,
    /// optional trace collector; `None` (the default) keeps every
    /// transfer untraced with zero overhead in the chunk loop
    tracer: Mutex<Option<Arc<Tracer>>>,
}

impl Orchestrator {
    /// Fails when the knowledge base has no surface sets: every ASM
    /// query path below relies on at least one set existing, so the
    /// invariant is enforced once here instead of panicking mid-transfer.
    pub fn new(
        kb: Arc<KnowledgeBase>,
        sp_model: Arc<StaticAnnModel>,
        annot_model: Arc<AnnOtModel>,
        cfg: OrchestratorConfig,
    ) -> Result<Orchestrator> {
        if kb.sets.is_empty() {
            crate::bail!(
                "orchestrator needs a non-empty knowledge base (no surface sets fitted)"
            );
        }
        let cache = Mutex::new(TuningCache::new(cfg.cache_capacity.max(1)));
        Ok(Orchestrator {
            kb,
            sp_model,
            annot_model,
            cfg,
            cache,
            tracer: Mutex::new(None),
        })
    }

    /// Attach (or detach, with `None`) a trace collector.  Every
    /// subsequent transfer opens a [`crate::util::trace::TraceScope`]
    /// keyed by its request id and records its full lifecycle; see
    /// `util::trace` for the determinism contract.
    pub fn set_tracer(&self, tracer: Option<Arc<Tracer>>) {
        *self.tracer.lock().unwrap_or_else(|e| e.into_inner()) = tracer;
    }

    fn tracer(&self) -> Option<Arc<Tracer>> {
        self.tracer.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    fn cache_enabled(&self) -> bool {
        self.cfg.cache_capacity > 0
    }

    /// Lock the tuning cache, recovering the guard if a worker thread
    /// panicked while holding it (the cache holds plain counters and
    /// tuning entries; any state it has is still internally consistent).
    fn lock_cache(&self) -> MutexGuard<'_, TuningCache> {
        self.cache.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Snapshot of the tuning cache's hit/miss/eviction counters.
    pub fn cache_stats(&self) -> CacheStats {
        self.lock_cache().stats()
    }

    /// Build the per-request optimizer.
    pub fn build_optimizer(&self, req: &TransferRequest) -> Box<dyn Optimizer> {
        let p = &req.profile;
        let d = &req.dataset;
        match req.model {
            OptimizerKind::Asm => {
                // `new` guarantees a non-empty knowledge base, so the
                // query's final fallback always yields a set; should
                // that invariant ever break, degrade to the untuned
                // defaults instead of crashing a live transfer.
                let Some(set) = self
                    .kb
                    .query(p.rtt_s, p.bandwidth_mbps, d.avg_file_mb, d.n_files)
                else {
                    return Box::new(NoOptimization);
                };
                Box::new(AsmOptimizer::new(DynamicTuner::new(
                    set.clone(),
                    self.cfg.tuner.clone(),
                )))
            }
            OptimizerKind::Harp => Box::new(Harp::plan(p, d)),
            OptimizerKind::AnnOt => Box::new(AnnOt::for_transfer(
                &self.annot_model,
                p.rtt_s,
                p.bandwidth_mbps,
                d.avg_file_mb,
                d.n_files,
                req.seed,
            )),
            OptimizerKind::Globus => Box::new(Globus::for_dataset(d)),
            OptimizerKind::StaticAnn => Box::new(StaticAnn::for_transfer(
                &self.sp_model,
                p.rtt_s,
                p.bandwidth_mbps,
                d.avg_file_mb,
                d.n_files,
            )),
            OptimizerKind::SingleChunk => Box::new(SingleChunk::plan(p, d, 16)),
            OptimizerKind::NelderMead => {
                Box::new(NelderMead::new(crate::Params::new(2, 2, 4), p.max_param, 20))
            }
            OptimizerKind::NoOpt => Box::new(NoOptimization),
        }
    }

    /// Cache-aware optimizer build for the *initial* attempt of an ASM
    /// transfer: consults the historical tuning cache and warm-starts
    /// the controller on a hit.  Returns the optimizer plus the cache
    /// verdict (`None` = cache not consulted: disabled or baseline
    /// model).  The post-fault re-tune path deliberately bypasses this
    /// — post-fault conditions rarely match the cached operating point.
    fn build_optimizer_cached(&self, req: &TransferRequest) -> (Box<dyn Optimizer>, Option<bool>) {
        if !self.cache_enabled() || req.model != OptimizerKind::Asm {
            return (self.build_optimizer(req), None);
        }
        let p = &req.profile;
        let d = &req.dataset;
        let fp = Fingerprint::of(p.rtt_s, p.bandwidth_mbps, d.avg_file_mb, d.n_files);
        let cached = self.lock_cache().get(fp);
        match cached {
            Some(entry) => {
                // Same invariant as build_optimizer: fall back to the
                // cold-start path rather than panic if the knowledge
                // base somehow lost its sets.
                let Some(set) = self
                    .kb
                    .query(p.rtt_s, p.bandwidth_mbps, d.avg_file_mb, d.n_files)
                else {
                    return (self.build_optimizer(req), Some(false));
                };
                let tuner =
                    DynamicTuner::with_cached(set.clone(), self.cfg.tuner.clone(), &entry);
                (Box::new(AsmOptimizer::new(tuner)), Some(true))
            }
            None => (self.build_optimizer(req), Some(false)),
        }
    }

    /// Run one transfer to completion (synchronous).
    pub fn execute(&self, req: &TransferRequest) -> TransferReport {
        self.execute_with_faults(req, None).report
    }

    /// Run one transfer under an optional fault schedule, with
    /// checkpoint/resume and retry-with-backoff around failed chunk
    /// attempts.  With `fault_plan = None` this is exactly
    /// [`Orchestrator::execute`].
    ///
    /// Recovery loop per chunk: an [`ChunkFault::EndpointStall`] burns
    /// the detection timeout, then the scheduler's [`RetryPolicy`]
    /// schedules exponentially-backed-off retries of the *same* chunk
    /// (the checkpoint keeps completed bytes).  Once a retried chunk
    /// goes through, an ASM transfer re-queries the knowledge base and
    /// restarts the bisection — the paper's re-tuning path — because
    /// post-fault conditions rarely match the pre-fault surface.
    /// Exhausting the budget marks the transfer `Failed` and returns
    /// the partial report.
    ///
    /// [`RetryPolicy`]: crate::coordinator::scheduler::RetryPolicy
    pub fn execute_with_faults(
        &self,
        req: &TransferRequest,
        fault_plan: Option<FaultPlan>,
    ) -> RecoveryReport {
        let mut env = SimEnv::new(req.profile.clone(), req.seed).with_phase(req.phase_s);
        if let Some(plan) = fault_plan {
            env = env.with_faults(plan);
        }
        let tracer = self.tracer();
        let mut scope = Tracer::scope_opt(tracer.as_ref(), req.id);
        let (mut optimizer, cache_hit) = self.build_optimizer_cached(req);
        if let Some(hit) = cache_hit {
            scope.event(
                "cache.consult",
                0.0,
                vec![
                    ("hit", Value::Bool(hit)),
                    ("capacity", Value::Num(self.cfg.cache_capacity as f64)),
                ],
            );
            scope.count(if hit { "cache.hits" } else { "cache.misses" }, 1);
        }
        let mut state = TransferState::Queued;
        state.transition(TransferState::Sampling);
        scope.event(
            "state",
            0.0,
            vec![("to", Value::str(state.label()))],
        );

        let expected = req.profile.bandwidth_mbps / 4.0;
        let plan = plan_chunks(&req.profile, &req.dataset, expected, &self.cfg.scheduler);
        let retry = self.cfg.scheduler.retry.clone();

        let total_mb = req.dataset.total_mb();
        let start = env.now_s;
        let mut remaining = total_mb;
        let mut transferred = 0.0f64;
        let mut samples: Vec<ChunkSample> = Vec::new();
        let mut last_th: Option<f64> = None;
        let mut prev_params: Option<crate::Params> = None;
        let mut idx = 0usize;
        let mut retries = 0usize;
        let mut backoff_total_s = 0.0f64;
        let mut resumed_chunks = 0usize;
        let mut last_fault = env.fault_state();

        while remaining > 1e-9 {
            if idx == self.cfg.sampling_chunks && state == TransferState::Sampling {
                state.transition(TransferState::Streaming);
                scope.event(
                    "state",
                    env.now_s - start,
                    vec![("to", Value::str(state.label()))],
                );
            }
            // fault-condition transition (injection onset or expiry)
            let fault_now = env.fault_state();
            if fault_now != last_fault {
                scope.event(
                    "fault.state",
                    env.now_s - start,
                    fault_state_fields(&fault_now),
                );
                scope.count("fault.transitions", 1);
                last_fault = fault_now;
            }
            let chunk_mb = if idx < self.cfg.sampling_chunks {
                plan.sample_chunk_mb.min(remaining)
            } else {
                plan.stream_chunk_mb.min(remaining)
            };
            let files = ((chunk_mb / req.dataset.avg_file_mb).ceil() as u64).max(1);
            let chunk = Dataset::new(files, chunk_mb / files as f64);

            let params = optimizer
                .next_params(last_th)
                .clamp(req.profile.max_param);
            // stamp the tuner's clock-less decision events (sampling
            // steps, convergence, alarms, re-tunes) with the decision
            // time
            scope.stamp(env.now_s - start, optimizer.drain_trace());

            // retry-with-backoff loop: the chunk (and the bytes behind
            // it) is the checkpoint unit
            let mut attempt = 1usize;
            let attempt_result = loop {
                match env.try_transfer_chunk(params, &chunk, prev_params) {
                    Ok(ok) => break Some(ok),
                    Err(ChunkFault::EndpointStall { .. }) => {
                        scope.event(
                            "chunk.stall",
                            env.now_s - start,
                            vec![
                                ("chunk", Value::Num(idx as f64)),
                                ("attempt", Value::Num(attempt as f64)),
                            ],
                        );
                        scope.count("chunk.stalls", 1);
                        if state != TransferState::Recovering {
                            state.transition(TransferState::Recovering);
                            scope.event(
                                "state",
                                env.now_s - start,
                                vec![("to", Value::str(state.label()))],
                            );
                        }
                        if attempt >= retry.max_attempts {
                            break None;
                        }
                        let wait = retry.backoff_s(attempt);
                        scope.event(
                            "retry.backoff",
                            env.now_s - start,
                            vec![
                                ("chunk", Value::Num(idx as f64)),
                                ("attempt", Value::Num(attempt as f64)),
                                ("wait_s", Value::Num(wait)),
                            ],
                        );
                        scope.observe("retry.backoff_s", wait);
                        env.now_s += wait;
                        backoff_total_s += wait;
                        retries += 1;
                        attempt += 1;
                    }
                }
            };
            let Some((th, _dur)) = attempt_result else {
                state.transition(TransferState::Failed);
                scope.event(
                    "transfer.failed",
                    env.now_s - start,
                    vec![
                        ("chunk", Value::Num(idx as f64)),
                        ("attempts", Value::Num(attempt as f64)),
                        ("remaining_mb", Value::Num(remaining)),
                    ],
                );
                break;
            };
            let recovered = state == TransferState::Recovering;
            if recovered {
                resumed_chunks += 1;
                state.transition(if idx < self.cfg.sampling_chunks {
                    TransferState::Sampling
                } else {
                    TransferState::Streaming
                });
                scope.event(
                    "chunk.resumed",
                    env.now_s - start,
                    vec![
                        ("chunk", Value::Num(idx as f64)),
                        ("to", Value::str(state.label())),
                    ],
                );
                scope.count("chunks.resumed", 1);
            }
            samples.push(ChunkSample {
                t_s: env.now_s - start,
                params,
                throughput_mbps: th,
                chunk_mb,
                penalty_s: prev_params
                    .map(|q| env.model.param_change_penalty_s(q, params))
                    .unwrap_or(0.0),
            });
            scope.count("chunks", 1);
            scope.observe("chunk.throughput_mbps", th);
            remaining -= chunk_mb;
            transferred += chunk_mb;
            if recovered && req.model == OptimizerKind::Asm {
                // confirmed fault: re-query the knowledge base and
                // restart the ASM bisection on current conditions
                optimizer = self.build_optimizer(req);
                last_th = None;
                scope.event(
                    "asm.requery",
                    env.now_s - start,
                    vec![("chunk", Value::Num(idx as f64))],
                );
                scope.count("asm.requeries", 1);
            } else {
                last_th = Some(th);
            }
            prev_params = Some(params);
            idx += 1;
        }

        let completed = state != TransferState::Failed;
        if completed {
            if state == TransferState::Sampling {
                state.transition(TransferState::Streaming);
            }
            state.transition(TransferState::Done);
        }
        // catch decision events minted by the last `next_params` of a
        // failed run (a completed run has already drained everything)
        scope.stamp(env.now_s - start, optimizer.drain_trace());
        scope.event(
            "state",
            env.now_s - start,
            vec![("to", Value::str(state.label()))],
        );
        scope.count(
            if completed {
                "transfers.completed"
            } else {
                "transfers.failed"
            },
            1,
        );

        // memoize the converged operating point for future requests
        // with the same (network, dataset) fingerprint
        if completed && self.cache_enabled() && req.model == OptimizerKind::Asm {
            if let Some(entry) = optimizer.cache_entry() {
                let fp = Fingerprint::of(
                    req.profile.rtt_s,
                    req.profile.bandwidth_mbps,
                    req.dataset.avg_file_mb,
                    req.dataset.n_files,
                );
                let evicted = {
                    let mut cache = self.lock_cache();
                    let before = cache.stats().evictions;
                    cache.put(fp, entry);
                    cache.stats().evictions - before
                };
                scope.event(
                    "cache.memoize",
                    env.now_s - start,
                    vec![("evicted", Value::Num(evicted as f64))],
                );
                scope.count("cache.memoizations", 1);
                scope.count("cache.evictions", evicted);
            }
        }

        let outcome = TransferOutcome {
            total_mb: transferred,
            duration_s: env.now_s - start,
            samples,
        };
        let mut report = TransferReport::from_outcome(
            optimizer.name(),
            req.profile.name,
            &outcome,
            optimizer.predicted_th(),
            optimizer.samples_used().min(self.cfg.sampling_chunks),
        );
        report.cache_hit = cache_hit;
        let mut span_fields = vec![
            ("model", Value::str(report.model.clone())),
            ("network", Value::str(report.network.clone())),
            ("completed", Value::Bool(completed)),
            ("total_mb", Value::Num(report.total_mb)),
            ("avg_mbps", Value::Num(report.avg_throughput_mbps)),
            ("steady_mbps", Value::Num(report.steady_throughput_mbps)),
            ("param_changes", Value::Num(report.param_changes as f64)),
            ("sample_transfers", Value::Num(report.sample_transfers as f64)),
            ("stalled_chunks", Value::Num(report.stalled_chunks as f64)),
            ("retries", Value::Num(retries as f64)),
            ("backoff_total_s", Value::Num(backoff_total_s)),
        ];
        if let Some(acc) = report.accuracy_pct {
            span_fields.push(("accuracy_pct", Value::Num(acc)));
        }
        scope.span("transfer", 0.0, outcome.duration_s, span_fields);
        scope.count("retries", retries as u64);
        scope.observe("transfer.duration_s", outcome.duration_s);
        if report.steady_throughput_mbps > 0.0 {
            scope.observe("steady.throughput_mbps", report.steady_throughput_mbps);
        }
        drop(scope); // flush into the tracer at a single point
        RecoveryReport {
            report,
            retries,
            backoff_total_s,
            resumed_chunks,
            completed,
            checkpoint: Checkpoint {
                chunk_idx: idx,
                transferred_mb: transferred,
                remaining_mb: remaining.max(0.0),
            },
        }
    }

    /// Fan a request batch out to `cfg.workers` worker threads.
    pub fn run_batch(&self, requests: Vec<TransferRequest>) -> Vec<TransferReport> {
        let n = requests.len();
        if n == 0 {
            return Vec::new();
        }
        let (req_tx, req_rx) = mpsc::channel::<TransferRequest>();
        let (rep_tx, rep_rx) = mpsc::channel::<(u64, TransferReport)>();
        let req_rx = Arc::new(Mutex::new(req_rx));
        for r in requests {
            // the receiver lives until the scope below drains it, so a
            // send can only fail if the process is already unwinding
            if req_tx.send(r).is_err() {
                break;
            }
        }
        drop(req_tx);

        // pallas-lint: allow(ad-hoc-thread, id-keyed mpsc batch pool predates util::par; results are re-sorted by request id and every transfer is seed-driven, so scheduling cannot leak into the output)
        std::thread::scope(|scope| {
            for _ in 0..self.cfg.workers.max(1) {
                let rx = Arc::clone(&req_rx);
                let tx = rep_tx.clone();
                // pallas-lint: allow(ad-hoc-thread, worker of the deterministic batch pool above)
                scope.spawn(move || loop {
                    let req = {
                        let guard = rx.lock().unwrap_or_else(|e| e.into_inner());
                        guard.recv()
                    };
                    match req {
                        Ok(r) => {
                            let report = self.execute(&r);
                            if tx.send((r.id, report)).is_err() {
                                break;
                            }
                        }
                        Err(_) => break,
                    }
                });
            }
            drop(rep_tx);
            let mut out: Vec<(u64, TransferReport)> = rep_rx.iter().collect();
            out.sort_by_key(|(id, _)| *id);
            out.into_iter().map(|(_, r)| r).collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logs::generator::{generate_history, GeneratorConfig};
    use crate::offline::pipeline::OfflineConfig;
    use std::sync::OnceLock;

    fn orchestrator() -> &'static Orchestrator {
        static ORCH: OnceLock<Orchestrator> = OnceLock::new();
        ORCH.get_or_init(|| {
            let cfg = GeneratorConfig {
                days: 14.0,
                transfers_per_hour: 10.0,
                seed: 42,
            };
            let logs = generate_history(&NetProfile::xsede(), &cfg);
            let kb = KnowledgeBase::build_native(logs.clone(), OfflineConfig::default());
            let sp = StaticAnnModel::train(&logs, 32, 1);
            let annot = AnnOtModel::train(&logs, 32, 1);
            Orchestrator::new(
                Arc::new(kb),
                Arc::new(sp),
                Arc::new(annot),
                OrchestratorConfig::default(),
            )
            .expect("generated history yields a non-empty knowledge base")
        })
    }

    fn request(id: u64, model: OptimizerKind) -> TransferRequest {
        TransferRequest {
            id,
            profile: NetProfile::xsede(),
            dataset: Dataset::new(64, 512.0), // 32 GB
            model,
            seed: 7 + id,
            phase_s: 7_200.0, // off-peak
        }
    }

    #[test]
    fn executes_all_models() {
        let orch = orchestrator();
        for kind in OptimizerKind::all() {
            let r = orch.execute(&request(0, kind));
            assert!(
                r.avg_throughput_mbps > 0.0,
                "{}: no throughput",
                kind.label()
            );
            assert!((r.total_mb - 32_768.0).abs() < 1e-6);
            assert!(r.duration_s > 0.0);
        }
    }

    #[test]
    fn asm_beats_noopt_handily() {
        let orch = orchestrator();
        let asm = orch.execute(&request(1, OptimizerKind::Asm));
        let noopt = orch.execute(&request(1, OptimizerKind::NoOpt));
        assert!(
            asm.avg_throughput_mbps > 2.0 * noopt.avg_throughput_mbps,
            "ASM {} vs NoOpt {}",
            asm.avg_throughput_mbps,
            noopt.avg_throughput_mbps
        );
    }

    #[test]
    fn asm_uses_few_samples_and_predicts() {
        let orch = orchestrator();
        let r = orch.execute(&request(2, OptimizerKind::Asm));
        assert!(r.sample_transfers <= 4, "{}", r.sample_transfers);
        assert!(r.predicted_mbps.is_some());
        assert!(r.accuracy_pct.unwrap() > 0.0);
    }

    #[test]
    fn batch_matches_sequential() {
        let orch = orchestrator();
        let reqs: Vec<TransferRequest> = (0..6)
            .map(|i| request(i, OptimizerKind::Asm))
            .collect();
        let seq: Vec<TransferReport> =
            reqs.iter().map(|r| orch.execute(r)).collect();
        let par = orch.run_batch(reqs);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            // identical seeds -> identical simulations, regardless of
            // which worker ran them
            assert_eq!(a.avg_throughput_mbps, b.avg_throughput_mbps);
            assert_eq!(a.final_params, b.final_params);
        }
    }

    #[test]
    fn empty_batch() {
        assert!(orchestrator().run_batch(vec![]).is_empty());
    }

    #[test]
    fn tuning_cache_warm_starts_repeat_fingerprints() {
        let base = orchestrator();
        let orch = Orchestrator::new(
            Arc::clone(&base.kb),
            Arc::clone(&base.sp_model),
            Arc::clone(&base.annot_model),
            OrchestratorConfig {
                cache_capacity: 8,
                ..OrchestratorConfig::default()
            },
        )
        .expect("non-empty knowledge base");
        let req = request(1, OptimizerKind::Asm);

        let cold = orch.execute(&req);
        assert_eq!(cold.cache_hit, Some(false));
        let s = orch.cache_stats();
        assert_eq!((s.hits, s.misses, s.insertions), (0, 1, 1));

        let warm = orch.execute(&req);
        assert_eq!(warm.cache_hit, Some(true));
        assert_eq!(warm.sample_transfers, 0, "warm start skips probing");
        assert_eq!(orch.cache_stats().hits, 1);
        // both runs stream the full dataset either way
        assert!((warm.total_mb - cold.total_mb).abs() < 1e-6);

        // baselines never consult the cache …
        let noopt = orch.execute(&request(2, OptimizerKind::NoOpt));
        assert_eq!(noopt.cache_hit, None);
        assert_eq!(orch.cache_stats().hits + orch.cache_stats().misses, 2);
        // … and the default config keeps it disabled entirely
        assert_eq!(base.execute(&req).cache_hit, None);
    }

    fn stall(t_start_s: f64, duration_s: f64) -> crate::faults::FaultPlan {
        crate::faults::FaultPlan {
            events: vec![crate::faults::FaultEvent {
                kind: crate::faults::FaultKind::EndpointStall,
                t_start_s,
                duration_s,
                magnitude: 1.0,
            }],
        }
    }

    #[test]
    fn faultless_plan_matches_plain_execute() {
        let orch = orchestrator();
        let req = request(3, OptimizerKind::Asm);
        let plain = orch.execute(&req);
        let rr = orch.execute_with_faults(&req, Some(crate::faults::FaultPlan::empty()));
        assert!(rr.completed);
        assert_eq!(rr.retries, 0);
        assert_eq!(rr.resumed_chunks, 0);
        assert_eq!(rr.backoff_total_s, 0.0);
        assert_eq!(rr.report.avg_throughput_mbps, plain.avg_throughput_mbps);
        assert_eq!(rr.report.final_params, plain.final_params);
        assert!(rr.checkpoint.remaining_mb < 1e-6);
    }

    #[test]
    fn stall_recovery_retries_with_backoff_then_resumes() {
        let orch = orchestrator();
        let req = request(4, OptimizerKind::Asm);
        // stall covers [0, 20): attempts at t = 0, 7, 16 fail (each
        // burns the 5 s detection timeout, then backs off 2/4/8 s);
        // the fourth attempt at t = 29 goes through
        let rr = orch.execute_with_faults(&req, Some(stall(0.0, 20.0)));
        assert!(rr.completed);
        assert_eq!(rr.retries, 3);
        assert_eq!(rr.backoff_total_s, 2.0 + 4.0 + 8.0);
        assert_eq!(rr.resumed_chunks, 1);
        // resume, not restart: every byte is delivered exactly once
        assert!((rr.report.total_mb - req.dataset.total_mb()).abs() < 1e-6);
        assert!(rr.checkpoint.remaining_mb < 1e-6);
        assert!((rr.checkpoint.transferred_mb - req.dataset.total_mb()).abs() < 1e-6);
    }

    #[test]
    fn retry_budget_exhaustion_fails_cleanly() {
        let orch = orchestrator();
        let req = request(5, OptimizerKind::Asm);
        // permanent stall from t = 0: all 5 attempts fail, no data moves
        let rr = orch.execute_with_faults(&req, Some(stall(0.0, 1e9)));
        assert!(!rr.completed);
        assert_eq!(rr.retries, 4); // 5 attempts = 4 retries
        assert_eq!(rr.backoff_total_s, 2.0 + 4.0 + 8.0 + 16.0);
        assert_eq!(rr.checkpoint.chunk_idx, 0);
        assert_eq!(rr.checkpoint.transferred_mb, 0.0);
        assert!((rr.checkpoint.remaining_mb - req.dataset.total_mb()).abs() < 1e-6);
        assert_eq!(rr.report.avg_throughput_mbps, 0.0);
        assert!(rr.report.duration_s > 0.0, "dead time is still charged");
    }

    #[test]
    fn mid_transfer_stall_keeps_completed_chunks() {
        let orch = orchestrator();
        // NoOpt moves one slow chunk (> 30 s) before hitting the
        // permanent stall, so the checkpoint must hold partial progress
        let req = request(6, OptimizerKind::NoOpt);
        let rr = orch.execute_with_faults(&req, Some(stall(30.0, 1e9)));
        assert!(!rr.completed);
        assert!(rr.checkpoint.chunk_idx >= 1);
        assert!(rr.checkpoint.transferred_mb > 0.0);
        assert!(
            (rr.checkpoint.transferred_mb + rr.checkpoint.remaining_mb
                - req.dataset.total_mb())
            .abs()
                < 1e-6,
            "checkpoint partitions the dataset exactly"
        );
        assert_eq!(rr.report.total_mb, rr.checkpoint.transferred_mb);
    }
}
