//! The leader loop: accepts transfer requests, builds the right
//! optimizer for each (ASM from the knowledge base, or any §5
//! baseline), drives the chunked transfer through the simulator, and
//! emits [`TransferReport`]s.  Batch mode fans requests out to a
//! worker-thread pool over std mpsc channels.

use crate::baselines::ann_ot::{AnnOt, AnnOtModel};
use crate::baselines::api::{AsmOptimizer, NoOptimization, Optimizer, OptimizerKind};
use crate::baselines::globus::Globus;
use crate::baselines::harp::Harp;
use crate::baselines::nelder_mead::NelderMead;
use crate::baselines::single_chunk::SingleChunk;
use crate::baselines::static_ann::{StaticAnn, StaticAnnModel};
use crate::coordinator::metrics::TransferReport;
use crate::coordinator::scheduler::{plan_chunks, SchedulerConfig};
use crate::coordinator::state::TransferState;
use crate::offline::pipeline::KnowledgeBase;
use crate::online::controller::{DynamicTuner, TunerConfig};
use crate::sim::dataset::Dataset;
use crate::sim::engine::{ChunkSample, SimEnv, TransferOutcome};
use crate::sim::profile::NetProfile;
use std::sync::{mpsc, Arc, Mutex};

/// One transfer job.
#[derive(Debug, Clone)]
pub struct TransferRequest {
    pub id: u64,
    pub profile: NetProfile,
    pub dataset: Dataset,
    pub model: OptimizerKind,
    pub seed: u64,
    /// diurnal phase offset (seconds): pins peak vs off-peak
    pub phase_s: f64,
}

#[derive(Debug, Clone)]
pub struct OrchestratorConfig {
    pub workers: usize,
    pub scheduler: SchedulerConfig,
    pub tuner: TunerConfig,
    /// chunks transferred at sample size before switching to stream
    /// size (covers every model's probing phase)
    pub sampling_chunks: usize,
}

impl Default for OrchestratorConfig {
    fn default() -> Self {
        OrchestratorConfig {
            workers: 4,
            scheduler: SchedulerConfig::default(),
            tuner: TunerConfig::default(),
            sampling_chunks: 6,
        }
    }
}

/// The transfer service.
pub struct Orchestrator {
    pub kb: Arc<KnowledgeBase>,
    pub sp_model: Arc<StaticAnnModel>,
    pub annot_model: Arc<AnnOtModel>,
    pub cfg: OrchestratorConfig,
}

impl Orchestrator {
    pub fn new(
        kb: Arc<KnowledgeBase>,
        sp_model: Arc<StaticAnnModel>,
        annot_model: Arc<AnnOtModel>,
        cfg: OrchestratorConfig,
    ) -> Orchestrator {
        Orchestrator {
            kb,
            sp_model,
            annot_model,
            cfg,
        }
    }

    /// Build the per-request optimizer.
    pub fn build_optimizer(&self, req: &TransferRequest) -> Box<dyn Optimizer> {
        let p = &req.profile;
        let d = &req.dataset;
        match req.model {
            OptimizerKind::Asm => {
                let set = self
                    .kb
                    .query(p.rtt_s, p.bandwidth_mbps, d.avg_file_mb, d.n_files)
                    .expect("knowledge base has surfaces")
                    .clone();
                Box::new(AsmOptimizer::new(DynamicTuner::new(
                    set,
                    self.cfg.tuner.clone(),
                )))
            }
            OptimizerKind::Harp => Box::new(Harp::plan(p, d)),
            OptimizerKind::AnnOt => Box::new(AnnOt::for_transfer(
                &self.annot_model,
                p.rtt_s,
                p.bandwidth_mbps,
                d.avg_file_mb,
                d.n_files,
                req.seed,
            )),
            OptimizerKind::Globus => Box::new(Globus::for_dataset(d)),
            OptimizerKind::StaticAnn => Box::new(StaticAnn::for_transfer(
                &self.sp_model,
                p.rtt_s,
                p.bandwidth_mbps,
                d.avg_file_mb,
                d.n_files,
            )),
            OptimizerKind::SingleChunk => Box::new(SingleChunk::plan(p, d, 16)),
            OptimizerKind::NelderMead => {
                Box::new(NelderMead::new(crate::Params::new(2, 2, 4), p.max_param, 20))
            }
            OptimizerKind::NoOpt => Box::new(NoOptimization),
        }
    }

    /// Run one transfer to completion (synchronous).
    pub fn execute(&self, req: &TransferRequest) -> TransferReport {
        let mut env = SimEnv::new(req.profile.clone(), req.seed).with_phase(req.phase_s);
        let mut optimizer = self.build_optimizer(req);
        let mut state = TransferState::Queued;
        state.transition(TransferState::Sampling);

        let expected = req.profile.bandwidth_mbps / 4.0;
        let plan = plan_chunks(&req.profile, &req.dataset, expected, &self.cfg.scheduler);

        let total_mb = req.dataset.total_mb();
        let start = env.now_s;
        let mut remaining = total_mb;
        let mut samples: Vec<ChunkSample> = Vec::new();
        let mut last_th: Option<f64> = None;
        let mut prev_params: Option<crate::Params> = None;
        let mut idx = 0usize;

        while remaining > 1e-9 {
            if idx == self.cfg.sampling_chunks && state == TransferState::Sampling {
                state.transition(TransferState::Streaming);
            }
            let chunk_mb = if idx < self.cfg.sampling_chunks {
                plan.sample_chunk_mb.min(remaining)
            } else {
                plan.stream_chunk_mb.min(remaining)
            };
            let files = ((chunk_mb / req.dataset.avg_file_mb).ceil() as u64).max(1);
            let chunk = Dataset::new(files, chunk_mb / files as f64);

            let params = optimizer
                .next_params(last_th)
                .clamp(req.profile.max_param);
            let (th, dur) = env.transfer_chunk(params, &chunk, prev_params);
            samples.push(ChunkSample {
                t_s: env.now_s - start,
                params,
                throughput_mbps: th,
                chunk_mb,
                penalty_s: prev_params
                    .map(|q| env.model.param_change_penalty_s(q, params))
                    .unwrap_or(0.0),
            });
            let _ = dur;
            remaining -= chunk_mb;
            last_th = Some(th);
            prev_params = Some(params);
            idx += 1;
        }
        if state == TransferState::Sampling {
            state.transition(TransferState::Streaming);
        }
        state.transition(TransferState::Done);

        let outcome = TransferOutcome {
            total_mb,
            duration_s: env.now_s - start,
            samples,
        };
        TransferReport::from_outcome(
            optimizer.name(),
            req.profile.name,
            &outcome,
            optimizer.predicted_th(),
            optimizer.samples_used().min(self.cfg.sampling_chunks),
        )
    }

    /// Fan a request batch out to `cfg.workers` worker threads.
    pub fn run_batch(&self, requests: Vec<TransferRequest>) -> Vec<TransferReport> {
        let n = requests.len();
        if n == 0 {
            return Vec::new();
        }
        let (req_tx, req_rx) = mpsc::channel::<TransferRequest>();
        let (rep_tx, rep_rx) = mpsc::channel::<(u64, TransferReport)>();
        let req_rx = Arc::new(Mutex::new(req_rx));
        for r in requests {
            req_tx.send(r).unwrap();
        }
        drop(req_tx);

        std::thread::scope(|scope| {
            for _ in 0..self.cfg.workers.max(1) {
                let rx = Arc::clone(&req_rx);
                let tx = rep_tx.clone();
                scope.spawn(move || loop {
                    let req = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match req {
                        Ok(r) => {
                            let report = self.execute(&r);
                            if tx.send((r.id, report)).is_err() {
                                break;
                            }
                        }
                        Err(_) => break,
                    }
                });
            }
            drop(rep_tx);
            let mut out: Vec<(u64, TransferReport)> = rep_rx.iter().collect();
            out.sort_by_key(|(id, _)| *id);
            out.into_iter().map(|(_, r)| r).collect()
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logs::generator::{generate_history, GeneratorConfig};
    use crate::offline::pipeline::OfflineConfig;
    use std::sync::OnceLock;

    fn orchestrator() -> &'static Orchestrator {
        static ORCH: OnceLock<Orchestrator> = OnceLock::new();
        ORCH.get_or_init(|| {
            let cfg = GeneratorConfig {
                days: 14.0,
                transfers_per_hour: 10.0,
                seed: 42,
            };
            let logs = generate_history(&NetProfile::xsede(), &cfg);
            let kb = KnowledgeBase::build_native(logs.clone(), OfflineConfig::default());
            let sp = StaticAnnModel::train(&logs, 32, 1);
            let annot = AnnOtModel::train(&logs, 32, 1);
            Orchestrator::new(
                Arc::new(kb),
                Arc::new(sp),
                Arc::new(annot),
                OrchestratorConfig::default(),
            )
        })
    }

    fn request(id: u64, model: OptimizerKind) -> TransferRequest {
        TransferRequest {
            id,
            profile: NetProfile::xsede(),
            dataset: Dataset::new(64, 512.0), // 32 GB
            model,
            seed: 7 + id,
            phase_s: 7_200.0, // off-peak
        }
    }

    #[test]
    fn executes_all_models() {
        let orch = orchestrator();
        for kind in OptimizerKind::all() {
            let r = orch.execute(&request(0, kind));
            assert!(
                r.avg_throughput_mbps > 0.0,
                "{}: no throughput",
                kind.label()
            );
            assert!((r.total_mb - 32_768.0).abs() < 1e-6);
            assert!(r.duration_s > 0.0);
        }
    }

    #[test]
    fn asm_beats_noopt_handily() {
        let orch = orchestrator();
        let asm = orch.execute(&request(1, OptimizerKind::Asm));
        let noopt = orch.execute(&request(1, OptimizerKind::NoOpt));
        assert!(
            asm.avg_throughput_mbps > 2.0 * noopt.avg_throughput_mbps,
            "ASM {} vs NoOpt {}",
            asm.avg_throughput_mbps,
            noopt.avg_throughput_mbps
        );
    }

    #[test]
    fn asm_uses_few_samples_and_predicts() {
        let orch = orchestrator();
        let r = orch.execute(&request(2, OptimizerKind::Asm));
        assert!(r.sample_transfers <= 4, "{}", r.sample_transfers);
        assert!(r.predicted_mbps.is_some());
        assert!(r.accuracy_pct.unwrap() > 0.0);
    }

    #[test]
    fn batch_matches_sequential() {
        let orch = orchestrator();
        let reqs: Vec<TransferRequest> = (0..6)
            .map(|i| request(i, OptimizerKind::Asm))
            .collect();
        let seq: Vec<TransferReport> =
            reqs.iter().map(|r| orch.execute(r)).collect();
        let par = orch.run_batch(reqs);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            // identical seeds -> identical simulations, regardless of
            // which worker ran them
            assert_eq!(a.avg_throughput_mbps, b.avg_throughput_mbps);
            assert_eq!(a.final_params, b.final_params);
        }
    }

    #[test]
    fn empty_batch() {
        assert!(orchestrator().run_batch(vec![]).is_empty());
    }
}
