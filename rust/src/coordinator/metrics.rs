//! Transfer accounting and the paper's accuracy metric.
//!
//! Eq 21 defines `|T_achieved − T_predict| / T_predict × 100` — as
//! written that is a relative *error*; the paper reports "93% accuracy"
//! meaning `100 − error`, which is what [`accuracy_pct`] returns
//! (clamped at 0 for wild misses).

use crate::sim::engine::TransferOutcome;
use crate::Params;

pub use crate::offline::cache::CacheStats;

/// Eq-21 style accuracy in percent.
pub fn accuracy_pct(achieved: f64, predicted: f64) -> f64 {
    if predicted <= 0.0 {
        return 0.0;
    }
    (100.0 - (achieved - predicted).abs() / predicted * 100.0).max(0.0)
}

/// Report for one completed transfer.
#[derive(Debug, Clone)]
pub struct TransferReport {
    pub model: String,
    pub network: String,
    pub total_mb: f64,
    pub duration_s: f64,
    pub avg_throughput_mbps: f64,
    /// model-predicted throughput at its converged operating point
    pub predicted_mbps: Option<f64>,
    pub accuracy_pct: Option<f64>,
    pub sample_transfers: usize,
    pub param_changes: usize,
    pub final_params: Params,
    /// volume-weighted throughput of the *streaming* phase only (the
    /// paper compares steady-state achievable throughput)
    pub steady_throughput_mbps: f64,
    /// historical-tuning-cache verdict for this transfer: `Some(true)`
    /// warm-started from a cached operating point, `Some(false)` was a
    /// recorded miss, `None` means the cache was not consulted
    /// (disabled, or a non-ASM model)
    pub cache_hit: Option<bool>,
    /// chunks that recorded zero throughput (endpoint stalls under
    /// fault injection); excluded from `steady_throughput_mbps`
    pub stalled_chunks: usize,
}

impl TransferReport {
    pub fn from_outcome(
        model: &str,
        network: &str,
        outcome: &TransferOutcome,
        predicted: Option<f64>,
        sample_transfers: usize,
    ) -> TransferReport {
        // steady phase = samples after the LAST parameter change in the
        // whole outcome (a fault-recovery re-tune past the sampling
        // head moves the steady boundary with it), and never earlier
        // than the sampling head itself
        let n = outcome.samples.len();
        let head = sample_transfers.min(n);
        let last_change = outcome
            .samples
            .windows(2)
            .rposition(|w| w[0].params != w[1].params)
            .map(|i| i + 1)
            .unwrap_or(0);
        let start = head.max(last_change);
        let steady: &[_] = if start < n {
            &outcome.samples[start..]
        } else {
            // degenerate outcome (all chunks consumed by tuning): fall
            // back to everything after the last change
            &outcome.samples[last_change..]
        };
        // volume-weighted harmonic mean over non-stalled chunks; a
        // stalled chunk (0 throughput) would contribute infinite
        // seconds and collapse the estimate, so it is counted apart
        let stalled_chunks = outcome
            .samples
            .iter()
            .filter(|c| c.throughput_mbps <= 0.0)
            .count();
        let (mb, secs) = steady
            .iter()
            .filter(|c| c.throughput_mbps > 0.0)
            .fold((0.0, 0.0), |(mb, s), c| {
                (mb + c.chunk_mb, s + c.chunk_mb * 8.0 / c.throughput_mbps)
            });
        let steady_th = if secs > 0.0 { mb * 8.0 / secs } else { 0.0 };
        let avg = outcome.avg_throughput_mbps();
        TransferReport {
            model: model.to_string(),
            network: network.to_string(),
            total_mb: outcome.total_mb,
            duration_s: outcome.duration_s,
            avg_throughput_mbps: avg,
            predicted_mbps: predicted,
            accuracy_pct: predicted.map(|p| accuracy_pct(steady_th, p)),
            sample_transfers,
            param_changes: outcome.param_changes(),
            final_params: outcome
                .samples
                .last()
                .map(|c| c.params)
                .unwrap_or(Params::DEFAULT),
            steady_throughput_mbps: steady_th,
            cache_hit: None,
            stalled_chunks,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::ChunkSample;

    #[test]
    fn accuracy_basics() {
        assert_eq!(accuracy_pct(100.0, 100.0), 100.0);
        assert!((accuracy_pct(93.0, 100.0) - 93.0).abs() < 1e-12);
        assert!((accuracy_pct(107.0, 100.0) - 93.0).abs() < 1e-12);
        assert_eq!(accuracy_pct(500.0, 100.0), 0.0); // clamped
        assert_eq!(accuracy_pct(1.0, 0.0), 0.0);
    }

    fn outcome() -> TransferOutcome {
        let mk = |t, th, mb, params| ChunkSample {
            t_s: t,
            params,
            throughput_mbps: th,
            chunk_mb: mb,
            penalty_s: 0.0,
        };
        TransferOutcome {
            total_mb: 3_000.0,
            duration_s: 60.0,
            samples: vec![
                mk(10.0, 100.0, 500.0, Params::new(2, 2, 2)),
                mk(30.0, 400.0, 500.0, Params::new(8, 4, 8)),
                mk(50.0, 800.0, 1_000.0, Params::new(8, 4, 8)),
                mk(60.0, 800.0, 1_000.0, Params::new(8, 4, 8)),
            ],
        }
    }

    #[test]
    fn steady_phase_excludes_sampling_head() {
        let r = TransferReport::from_outcome("ASM", "xsede", &outcome(), Some(800.0), 2);
        // steady = last two chunks at 800
        assert!((r.steady_throughput_mbps - 800.0).abs() < 1e-9);
        assert!((r.accuracy_pct.unwrap() - 100.0).abs() < 1e-9);
        assert_eq!(r.sample_transfers, 2);
        assert_eq!(r.final_params, Params::new(8, 4, 8));
    }

    #[test]
    fn avg_includes_everything() {
        let r = TransferReport::from_outcome("GO", "xsede", &outcome(), None, 0);
        assert!((r.avg_throughput_mbps - 3_000.0 * 8.0 / 60.0).abs() < 1e-9);
        assert!(r.accuracy_pct.is_none());
        assert_eq!(r.stalled_chunks, 0);
    }

    #[test]
    fn post_head_retune_moves_steady_boundary() {
        // fault-recovery path: the ASM re-tunes at chunk 4, well past
        // the sampling head of 2.  The steady phase must start at the
        // last parameter change (chunk 4), not at the head — the old
        // head-only slicing mixed the pre-re-tune 800s into the
        // post-re-tune 300 steady state.
        let mk = |t, th, mb, params| ChunkSample {
            t_s: t,
            params,
            throughput_mbps: th,
            chunk_mb: mb,
            penalty_s: 0.0,
        };
        let o = TransferOutcome {
            total_mb: 4_000.0,
            duration_s: 90.0,
            samples: vec![
                mk(10.0, 100.0, 500.0, Params::new(2, 2, 2)),
                mk(20.0, 400.0, 500.0, Params::new(8, 4, 8)),
                mk(35.0, 800.0, 1_000.0, Params::new(8, 4, 8)),
                mk(50.0, 800.0, 1_000.0, Params::new(8, 4, 8)),
                mk(70.0, 300.0, 500.0, Params::new(4, 2, 4)), // re-tune
                mk(90.0, 300.0, 500.0, Params::new(4, 2, 4)),
            ],
        };
        let r = TransferReport::from_outcome("ASM", "xsede", &o, Some(300.0), 2);
        assert!(
            (r.steady_throughput_mbps - 300.0).abs() < 1e-9,
            "steady must cover only the post-re-tune chunks, got {}",
            r.steady_throughput_mbps
        );
        assert!((r.accuracy_pct.unwrap() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn stalled_chunks_do_not_collapse_steady_throughput() {
        // a stalled chunk (0 throughput under fault injection) used to
        // contribute infinite seconds, driving steady throughput and
        // accuracy to 0
        let mk = |t, th, mb| ChunkSample {
            t_s: t,
            params: Params::new(8, 4, 8),
            throughput_mbps: th,
            chunk_mb: mb,
            penalty_s: 0.0,
        };
        let o = TransferOutcome {
            total_mb: 1_500.0,
            duration_s: 40.0,
            samples: vec![
                mk(10.0, 500.0, 500.0),
                mk(25.0, 0.0, 500.0), // endpoint stall
                mk(40.0, 500.0, 500.0),
            ],
        };
        let r = TransferReport::from_outcome("ASM", "xsede", &o, Some(500.0), 0);
        assert_eq!(r.stalled_chunks, 1);
        assert!(
            (r.steady_throughput_mbps - 500.0).abs() < 1e-9,
            "stalled chunk must be excluded, got {}",
            r.steady_throughput_mbps
        );
        assert!((r.accuracy_pct.unwrap() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn all_tuning_outcome_falls_back_past_last_change() {
        // every chunk consumed by tuning (head == len): steady falls
        // back to the slice after the last change rather than panicking
        // or averaging pre-convergence noise
        let mk = |t, th, params| ChunkSample {
            t_s: t,
            params,
            throughput_mbps: th,
            chunk_mb: 500.0,
            penalty_s: 0.0,
        };
        let o = TransferOutcome {
            total_mb: 1_000.0,
            duration_s: 30.0,
            samples: vec![
                mk(10.0, 100.0, Params::new(2, 2, 2)),
                mk(30.0, 400.0, Params::new(8, 4, 8)),
            ],
        };
        let r = TransferReport::from_outcome("ASM", "xsede", &o, Some(400.0), 2);
        assert!((r.steady_throughput_mbps - 400.0).abs() < 1e-9);
    }
}
