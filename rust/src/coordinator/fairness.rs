//! Centralized vs distributed coordination (§3).
//!
//! The distributed mode — the default everywhere else in this crate —
//! lets each user's ASM instance sense the network independently.  The
//! centralized mode models the paper's alternative: "a central
//! scheduler can distribute the parameters to contending transfers
//! ... It has a global view of the network and contending transfers",
//! applicable when one administrative domain owns both endpoints.
//!
//! The central scheduler splits the *stream budget* (the total
//! cc × p the bottleneck profitably supports at the current load)
//! across active jobs, avoiding both the oscillation and the mutual
//! congestion that distributed sensing pays for.

use crate::offline::pipeline::SurfaceSet;
use crate::sim::multiuser::{UserCtx, UserPolicy};
use crate::Params;

/// Central scheduler with a global view of active jobs.
#[derive(Debug, Clone)]
pub struct CentralScheduler {
    /// bucket-optimal parameters for the current (estimated) load
    reference: Params,
    n_users: usize,
    max_param: u32,
}

impl CentralScheduler {
    /// Build from the knowledge base's surface set: the reference
    /// point is the median-load bucket's optimum (the same starting
    /// point ASM samples from, but divided fairly up front).
    pub fn new(set: &SurfaceSet, n_users: usize, max_param: u32) -> CentralScheduler {
        let reference = set.buckets[set.median_bucket()].optimal_params;
        CentralScheduler {
            reference,
            n_users: n_users.max(1),
            max_param,
        }
    }

    /// Parameters assigned to each of the n users: the reference
    /// stream budget divided across users (concurrency split first —
    /// processes are the expensive resource — with parallelism
    /// reduced only when concurrency alone cannot absorb the split).
    pub fn assignment(&self) -> Params {
        let n = self.n_users as u32;
        let total_budget = (self.reference.total_streams()).max(1);
        let per_user = (total_budget + n - 1) / n;
        // keep the reference's p:cc proportion under the reduced budget
        let p = self.reference.p.min(per_user).max(1);
        let cc = (per_user / p).max(1).min(self.max_param);
        Params::new(cc, p, self.reference.pp)
    }
}

/// A fixed-assignment user policy handed out by the central scheduler.
#[derive(Debug, Clone)]
pub struct CentralAssignment {
    params: Params,
}

impl CentralAssignment {
    pub fn new(params: Params) -> CentralAssignment {
        CentralAssignment { params }
    }
}

impl UserPolicy for CentralAssignment {
    fn decide(&mut self, _ctx: &UserCtx) -> Params {
        self.params
    }

    fn name(&self) -> &str {
        "central"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offline::confidence::ConfidenceRegion;
    use crate::offline::pipeline::LoadBucketSurfaces;
    use crate::offline::spline::BicubicSurface;
    use crate::offline::surface::{knot_lattice, FittedSurface, ThroughputSurface};

    fn set_with_optimum(optimal: Params) -> SurfaceSet {
        let xs = knot_lattice();
        let values: Vec<Vec<f64>> =
            xs.iter().map(|_| xs.iter().map(|_| 100.0).collect()).collect();
        let surface = BicubicSurface::fit(&xs, &xs, &values);
        let slice = ThroughputSurface {
            pp: optimal.pp,
            load_bucket: 0,
            load_intensity: 0.5,
            fitted: FittedSurface {
                surface,
                max_th: 100.0,
                max_at: (optimal.p as f64, optimal.cc as f64),
                grid_mean: 100.0,
                grid_std: 1.0,
            },
            confidence: ConfidenceRegion { sigma: 5.0, z: 2.0 },
            optimal_params: optimal,
            optimal_th: 100.0,
            n_obs: 10,
            coverage: 1.0,
        };
        SurfaceSet {
            cluster: 0,
            class: crate::sim::dataset::FileSizeClass::Large,
            buckets: vec![LoadBucketSurfaces {
                bucket: 0,
                load_intensity: 0.5,
                true_intensity: 0.5,
                slices: vec![slice],
                optimal_params: optimal,
                optimal_th: 100.0,
            }],
            sampling: vec![],
        }
    }

    #[test]
    fn splits_stream_budget_across_users() {
        let set = set_with_optimum(Params::new(16, 4, 8)); // 64 streams
        let sched = CentralScheduler::new(&set, 4, 32);
        let q = sched.assignment();
        assert_eq!(q.total_streams(), 16, "{q}"); // 64 / 4
        assert_eq!(q.pp, 8);
    }

    #[test]
    fn single_user_gets_everything() {
        let set = set_with_optimum(Params::new(16, 4, 8));
        let sched = CentralScheduler::new(&set, 1, 32);
        assert_eq!(sched.assignment().total_streams(), 64);
    }

    #[test]
    fn many_users_floor_at_one_stream() {
        let set = set_with_optimum(Params::new(2, 2, 8)); // 4 streams
        let sched = CentralScheduler::new(&set, 16, 32);
        let q = sched.assignment();
        assert_eq!(q.total_streams(), 1);
    }

    #[test]
    fn aggregate_does_not_exceed_reference_much() {
        for users in 1..=8usize {
            let set = set_with_optimum(Params::new(12, 4, 8)); // 48
            let sched = CentralScheduler::new(&set, users, 32);
            let q = sched.assignment();
            let total = q.total_streams() * users as u32;
            assert!(
                total <= 48 + users as u32 * 4,
                "users={users}: {total} streams aggregate"
            );
        }
    }
}
