//! Per-transfer lifecycle state machine.
//!
//! Transitions are validated: a transfer cannot stream before sampling
//! or resurrect after completion — the orchestrator relies on this to
//! keep its bookkeeping honest under concurrent workers.

/// Lifecycle of one transfer job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TransferState {
    Queued,
    /// ASM sample transfers in flight
    Sampling,
    /// bulk data moving at converged parameters
    Streaming,
    /// persistent deviation detected; re-selecting a surface
    Retuning,
    /// a chunk attempt failed (endpoint stall / fault); retrying with
    /// backoff from the last checkpoint
    Recovering,
    Done,
    Failed,
}

impl TransferState {
    /// Whether `self -> next` is a legal transition.
    pub fn can_transition(self, next: TransferState) -> bool {
        use TransferState::*;
        matches!(
            (self, next),
            (Queued, Sampling)
                | (Queued, Failed)
                | (Sampling, Streaming)
                | (Sampling, Recovering)
                | (Sampling, Failed)
                | (Streaming, Retuning)
                | (Streaming, Recovering)
                | (Streaming, Done)
                | (Streaming, Failed)
                | (Retuning, Streaming)
                | (Retuning, Failed)
                | (Recovering, Sampling)
                | (Recovering, Streaming)
                | (Recovering, Failed)
        )
    }

    /// Apply a transition, panicking on an illegal one (programmer
    /// error — the orchestrator must never attempt it).
    pub fn transition(&mut self, next: TransferState) {
        assert!(
            self.can_transition(next),
            "illegal transfer-state transition {self:?} -> {next:?}"
        );
        *self = next;
    }

    pub fn is_terminal(self) -> bool {
        matches!(self, TransferState::Done | TransferState::Failed)
    }

    /// Stable lowercase name (trace records, reports).
    pub fn label(self) -> &'static str {
        match self {
            TransferState::Queued => "queued",
            TransferState::Sampling => "sampling",
            TransferState::Streaming => "streaming",
            TransferState::Retuning => "retuning",
            TransferState::Recovering => "recovering",
            TransferState::Done => "done",
            TransferState::Failed => "failed",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use TransferState::*;

    #[test]
    fn happy_path() {
        let mut s = Queued;
        s.transition(Sampling);
        s.transition(Streaming);
        s.transition(Retuning);
        s.transition(Streaming);
        s.transition(Done);
        assert!(s.is_terminal());
    }

    #[test]
    fn illegal_transitions_rejected() {
        assert!(!Queued.can_transition(Streaming));
        assert!(!Done.can_transition(Sampling));
        assert!(!Sampling.can_transition(Retuning));
        assert!(!Failed.can_transition(Queued));
        assert!(!Queued.can_transition(Recovering));
        assert!(!Recovering.can_transition(Done));
        assert!(!Done.can_transition(Recovering));
    }

    #[test]
    fn recovery_paths() {
        // stall mid-stream, recover, finish
        let mut s = Queued;
        s.transition(Sampling);
        s.transition(Streaming);
        s.transition(Recovering);
        s.transition(Streaming);
        s.transition(Done);
        // stall during sampling, give up
        let mut s = Queued;
        s.transition(Sampling);
        s.transition(Recovering);
        s.transition(Failed);
        assert!(s.is_terminal());
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(Queued.label(), "queued");
        assert_eq!(Recovering.label(), "recovering");
        assert_eq!(Failed.label(), "failed");
    }

    #[test]
    #[should_panic(expected = "illegal transfer-state transition")]
    fn transition_panics_on_illegal() {
        let mut s = Queued;
        s.transition(Done);
    }
}
