//! `pallas-lint` — determinism & robustness lint over the crate
//! sources (see `twophase::analysis` for the rule registry).
//!
//! ```text
//! pallas-lint [--root DIR] [--json] [--baseline [PATH]]
//!             [--write-baseline] [--list-rules]
//! ```
//!
//! * no flags: scan and report every violation (exit 1 if any);
//! * `--baseline`: compare against the checked-in allowance file
//!   (default `<root>/../lint-baseline.txt`) and fail on new
//!   violations *and* on stale entries — this is the CI gate;
//! * `--write-baseline`: regenerate the allowance file from the
//!   current scan (for paying down or re-triaging debt);
//! * `--json`: machine-readable report on stdout.
//!
//! Exit codes: 0 clean, 1 violations / baseline drift, 2 usage or I/O
//! error.

use std::path::{Path, PathBuf};

use twophase::analysis::{baseline, rules, scan_tree, Violation};
use twophase::util::cli::Args;
use twophase::util::err::{Context, Result};

fn main() {
    let args = Args::from_env();
    match run(&args) {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("pallas-lint: error: {e}");
            std::process::exit(2);
        }
    }
}

fn violation_json(v: &Violation) -> twophase::util::json::Value {
    use twophase::util::json::Value;
    Value::obj(vec![
        ("rule", Value::str(v.rule)),
        ("path", Value::str(v.path.as_str())),
        ("line", Value::Num(v.line as f64)),
        ("snippet", Value::str(v.snippet.as_str())),
    ])
}

fn print_violations(vs: &[Violation]) {
    for v in vs {
        println!("{}:{}: [{}] {}", v.path, v.line, v.rule, v.snippet);
    }
}

fn run(args: &Args) -> Result<i32> {
    if args.flag("list-rules") {
        for r in rules::registry() {
            println!("{}  {:<18} {}", r.code, r.id, r.summary);
        }
        return Ok(0);
    }

    // Default root works both from rust/ (cargo run) and the repo root.
    let root: PathBuf = match args.get("root") {
        Some(r) => PathBuf::from(r),
        None if Path::new("src").is_dir() => PathBuf::from("src"),
        None => PathBuf::from("rust/src"),
    };
    if !root.is_dir() {
        twophase::bail!(
            "source root `{}` not found (pass --root DIR)",
            root.display()
        );
    }

    let mut violations = scan_tree(&root)?;
    violations.sort_by(|a, b| {
        (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule))
    });
    let json = args.flag("json");

    let baseline_path: PathBuf = match args.get("baseline") {
        Some("true") | None => root
            .parent()
            .unwrap_or(Path::new("."))
            .join("lint-baseline.txt"),
        Some(p) => PathBuf::from(p),
    };

    if args.flag("write-baseline") {
        std::fs::write(&baseline_path, baseline::render(&violations))
            .with_context(|| format!("write {}", baseline_path.display()))?;
        println!(
            "pallas-lint: wrote {} ({} entries)",
            baseline_path.display(),
            baseline::counts(&violations).len()
        );
        return Ok(0);
    }

    if args.flag("baseline") {
        let text = std::fs::read_to_string(&baseline_path)
            .with_context(|| format!("read baseline {}", baseline_path.display()))?;
        let base = baseline::parse(&text)?;
        let cmp = baseline::compare(&base, &violations);
        if json {
            use twophase::util::json::Value;
            let over: Vec<Value> = cmp
                .over
                .iter()
                .flat_map(|(_, vs)| vs.iter().map(violation_json))
                .collect();
            let stale: Vec<Value> = cmp
                .stale
                .iter()
                .map(|d| {
                    Value::obj(vec![
                        ("rule", Value::str(d.rule.as_str())),
                        ("path", Value::str(d.path.as_str())),
                        ("allowed", Value::Num(d.allowed as f64)),
                        ("actual", Value::Num(d.actual as f64)),
                    ])
                })
                .collect();
            println!(
                "{}",
                Value::obj(vec![
                    ("clean", Value::Bool(cmp.clean())),
                    ("over", Value::Arr(over)),
                    ("stale", Value::Arr(stale)),
                ])
            );
        } else {
            for (d, vs) in &cmp.over {
                eprintln!(
                    "pallas-lint: {} in {}: {} violation(s), baseline allows {}",
                    d.rule, d.path, d.actual, d.allowed
                );
                print_violations(vs);
            }
            for d in &cmp.stale {
                eprintln!(
                    "pallas-lint: stale baseline entry: {} {} {} (now {}) — shrink or delete it",
                    d.rule, d.path, d.allowed, d.actual
                );
            }
            if cmp.clean() {
                println!("pallas-lint: clean against baseline");
            }
        }
        return Ok(if cmp.clean() { 0 } else { 1 });
    }

    if json {
        use twophase::util::json::Value;
        println!(
            "{}",
            Value::Arr(violations.iter().map(violation_json).collect())
        );
    } else if violations.is_empty() {
        println!("pallas-lint: clean");
    } else {
        print_violations(&violations);
        eprintln!("pallas-lint: {} violation(s)", violations.len());
    }
    Ok(if violations.is_empty() { 0 } else { 1 })
}
