//! Historical tuning cache: memoizes converged ASM operating points.
//!
//! The paper's core argument is that historical knowledge makes online
//! probing cheap; this module closes the remaining loop by remembering
//! the *outcome* of each ASM run.  A transfer request is reduced to a
//! discretized [`Fingerprint`] of its network profile and dataset
//! signature; when a later request lands in the same buckets, the
//! controller warm-starts the Adaptive Sampling Module at the cached
//! knowledge-base bucket instead of re-running the Algorithm-1
//! bisection from scratch.  The deviation monitor still guards against
//! stale entries — a warm start that no longer matches live conditions
//! trips the ordinary re-tuning path.
//!
//! The cache is a fixed-capacity LRU built from `std` only: a
//! deterministic-iteration `BTreeMap` keyed by fingerprint, plus a
//! tick-ordered `BTreeMap` index from access tick back to fingerprint,
//! so finding the least-recently-used entry is an O(log n) first-key
//! lookup instead of a full scan.  Ticks are unique (one per
//! operation), so the index is a bijection and eviction order is fully
//! deterministic.  Hit/miss/eviction counters are surfaced through
//! `coordinator::metrics`.

use std::collections::BTreeMap;

use crate::Params;

/// Discretized (network, dataset) signature.
///
/// Continuous quantities are bucketed on a half-octave log2 grid
/// (`round(log2(v) * 2)` — resolution factor ≈ 1.41×) so that runs
/// with near-identical conditions collide while genuinely different
/// regimes stay apart.  File count uses whole octaves: load scales
/// weakly with it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fingerprint {
    /// Half-octave bucket of round-trip time (seconds).
    pub rtt_bucket: i32,
    /// Half-octave bucket of bottleneck bandwidth (Mbps).
    pub bw_bucket: i32,
    /// Half-octave bucket of mean file size (MB).
    pub file_bucket: i32,
    /// Octave bucket of file count.
    pub count_bucket: i32,
}

/// Half-octave log2 bucket of a positive quantity.
fn half_octave(v: f64) -> i32 {
    (v.max(1e-9).log2() * 2.0).round() as i32
}

impl Fingerprint {
    pub fn of(rtt_s: f64, bandwidth_mbps: f64, avg_file_mb: f64, n_files: u64) -> Fingerprint {
        Fingerprint {
            rtt_bucket: half_octave(rtt_s),
            bw_bucket: half_octave(bandwidth_mbps),
            file_bucket: half_octave(avg_file_mb),
            count_bucket: (n_files as f64 + 1.0).log2().round() as i32,
        }
    }
}

/// A converged tuning decision worth replaying.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CachedTuning {
    /// Converged protocol parameters.
    pub params: Params,
    /// Throughput the knowledge base predicted for them (Mbps).
    pub predicted_mbps: f64,
    /// Index of the load-intensity bucket the ASM converged to —
    /// the warm-start anchor for `online::asm`.
    pub bucket: usize,
}

/// Monotonic counters describing cache effectiveness.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
}

impl CacheStats {
    /// Fraction of lookups that hit; 0 when no lookups happened.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Fixed-capacity LRU map from [`Fingerprint`] to [`CachedTuning`].
///
/// `map` holds the entries with their last-access tick; `by_tick` is
/// the inverse recency index.  Every mutation keeps the two in
/// lockstep: exactly one `by_tick` key per `map` entry.
#[derive(Debug)]
pub struct TuningCache {
    cap: usize,
    map: BTreeMap<Fingerprint, (CachedTuning, u64)>,
    by_tick: BTreeMap<u64, Fingerprint>,
    tick: u64,
    stats: CacheStats,
}

impl TuningCache {
    /// `cap` is clamped to at least 1 entry.
    pub fn new(cap: usize) -> TuningCache {
        TuningCache {
            cap: cap.max(1),
            map: BTreeMap::new(),
            by_tick: BTreeMap::new(),
            tick: 0,
            stats: CacheStats::default(),
        }
    }

    /// Look up a fingerprint, bumping its recency on hit and counting
    /// the outcome either way.
    pub fn get(&mut self, fp: Fingerprint) -> Option<CachedTuning> {
        self.tick += 1;
        match self.map.get_mut(&fp) {
            Some((tuning, tick)) => {
                self.by_tick.remove(tick);
                *tick = self.tick;
                self.by_tick.insert(self.tick, fp);
                self.stats.hits += 1;
                Some(*tuning)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Insert or refresh an entry, evicting the least-recently-used
    /// fingerprint when over capacity.  Ticks are unique, so the
    /// recency index has no ties and eviction is deterministic and
    /// O(log n): pop the smallest tick.
    pub fn put(&mut self, fp: Fingerprint, tuning: CachedTuning) {
        self.tick += 1;
        match self.map.insert(fp, (tuning, self.tick)) {
            Some((_, old_tick)) => {
                self.by_tick.remove(&old_tick);
            }
            None => {
                self.stats.insertions += 1;
            }
        }
        self.by_tick.insert(self.tick, fp);
        while self.map.len() > self.cap {
            let Some(oldest_tick) = self.by_tick.keys().next().copied() else {
                break; // unreachable: index mirrors a non-empty map
            };
            if let Some(victim) = self.by_tick.remove(&oldest_tick) {
                self.map.remove(&victim);
                self.stats.evictions += 1;
            }
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Drop all entries; counters are preserved (they are lifetime
    /// totals, not window totals).
    pub fn clear(&mut self) {
        self.map.clear();
        self.by_tick.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tuning(bucket: usize) -> CachedTuning {
        CachedTuning {
            params: Params::new(4, 2, 8),
            predicted_mbps: 1000.0 + bucket as f64,
            bucket,
        }
    }

    #[test]
    fn fingerprint_buckets_cluster_similar_conditions() {
        let a = Fingerprint::of(0.040, 1000.0, 512.0, 64);
        let b = Fingerprint::of(0.042, 1050.0, 540.0, 70);
        assert_eq!(a, b);
        let far = Fingerprint::of(0.120, 100.0, 8.0, 2000);
        assert_ne!(a, far);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut cache = TuningCache::new(2);
        let f1 = Fingerprint::of(0.01, 100.0, 10.0, 10);
        let f2 = Fingerprint::of(0.10, 1000.0, 100.0, 100);
        let f3 = Fingerprint::of(1.00, 10000.0, 1000.0, 1000);
        cache.put(f1, tuning(1));
        cache.put(f2, tuning(2));
        // Touch f1 so f2 becomes the LRU entry.
        assert!(cache.get(f1).is_some());
        cache.put(f3, tuning(3));
        assert_eq!(cache.len(), 2);
        assert!(cache.get(f2).is_none(), "LRU entry should be evicted");
        assert!(cache.get(f1).is_some());
        assert!(cache.get(f3).is_some());
        assert_eq!(cache.stats().evictions, 1);
        assert_eq!(cache.stats().insertions, 3);
    }

    #[test]
    fn refresh_does_not_count_as_insertion_or_grow() {
        let mut cache = TuningCache::new(2);
        let f1 = Fingerprint::of(0.01, 100.0, 10.0, 10);
        cache.put(f1, tuning(1));
        cache.put(f1, tuning(9));
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats().insertions, 1);
        assert_eq!(cache.get(f1).unwrap().bucket, 9);
    }

    #[test]
    fn hit_rate_counts_lookups() {
        let mut cache = TuningCache::new(4);
        let f1 = Fingerprint::of(0.01, 100.0, 10.0, 10);
        let f2 = Fingerprint::of(0.10, 1000.0, 100.0, 100);
        assert!(cache.get(f1).is_none()); // miss
        cache.put(f1, tuning(1));
        assert!(cache.get(f1).is_some()); // hit
        assert!(cache.get(f1).is_some()); // hit
        assert!(cache.get(f2).is_none()); // miss
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (2, 2));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
    }

    #[test]
    fn tick_index_matches_reference_lru_under_churn() {
        // Model-based check: replay an interleaved put/get workload
        // against a Vec-backed reference LRU and require identical
        // membership, plus a consistent recency index at every step.
        let cap = 8usize;
        let mut cache = TuningCache::new(cap);
        let mut model: Vec<Fingerprint> = Vec::new(); // front = LRU
        let fp = |i: i32| Fingerprint {
            rtt_bucket: i,
            bw_bucket: 0,
            file_bucket: 0,
            count_bucket: 0,
        };
        let mut state = 0x9E3779B97F4A7C15u64;
        for _ in 0..500 {
            // xorshift-style mixer; deterministic workload
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            let key = fp((state % 24) as i32);
            if state & 1 == 0 {
                cache.put(key, tuning(0));
                model.retain(|&k| k != key);
                model.push(key);
                if model.len() > cap {
                    model.remove(0);
                }
            } else {
                let hit = cache.get(key).is_some();
                let model_hit = model.contains(&key);
                assert_eq!(hit, model_hit);
                if model_hit {
                    model.retain(|&k| k != key);
                    model.push(key);
                }
            }
            assert_eq!(cache.len(), model.len());
            assert_eq!(cache.by_tick.len(), cache.map.len());
            for (tick, k) in &cache.by_tick {
                assert_eq!(cache.map.get(k).map(|(_, t)| *t), Some(*tick));
            }
        }
        // Final membership must agree exactly.
        for k in &model {
            assert!(cache.map.contains_key(k));
        }
    }

    #[test]
    fn capacity_zero_clamps_to_one() {
        let mut cache = TuningCache::new(0);
        let f1 = Fingerprint::of(0.01, 100.0, 10.0, 10);
        let f2 = Fingerprint::of(0.10, 1000.0, 100.0, 100);
        cache.put(f1, tuning(1));
        cache.put(f2, tuning(2));
        assert_eq!(cache.len(), 1);
    }
}
