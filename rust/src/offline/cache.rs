//! Historical tuning cache: memoizes converged ASM operating points.
//!
//! The paper's core argument is that historical knowledge makes online
//! probing cheap; this module closes the remaining loop by remembering
//! the *outcome* of each ASM run.  A transfer request is reduced to a
//! discretized [`Fingerprint`] of its network profile and dataset
//! signature; when a later request lands in the same buckets, the
//! controller warm-starts the Adaptive Sampling Module at the cached
//! knowledge-base bucket instead of re-running the Algorithm-1
//! bisection from scratch.  The deviation monitor still guards against
//! stale entries — a warm start that no longer matches live conditions
//! trips the ordinary re-tuning path.
//!
//! The cache is a fixed-capacity LRU built from `std` only: a
//! `HashMap` plus a monotonic access tick, with O(n) min-tick eviction
//! (capacities are tens of entries, not thousands).  Hit/miss/eviction
//! counters are surfaced through `coordinator::metrics`.

use std::collections::HashMap;

use crate::Params;

/// Discretized (network, dataset) signature.
///
/// Continuous quantities are bucketed on a half-octave log2 grid
/// (`round(log2(v) * 2)` — resolution factor ≈ 1.41×) so that runs
/// with near-identical conditions collide while genuinely different
/// regimes stay apart.  File count uses whole octaves: load scales
/// weakly with it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Fingerprint {
    /// Half-octave bucket of round-trip time (seconds).
    pub rtt_bucket: i32,
    /// Half-octave bucket of bottleneck bandwidth (Mbps).
    pub bw_bucket: i32,
    /// Half-octave bucket of mean file size (MB).
    pub file_bucket: i32,
    /// Octave bucket of file count.
    pub count_bucket: i32,
}

/// Half-octave log2 bucket of a positive quantity.
fn half_octave(v: f64) -> i32 {
    (v.max(1e-9).log2() * 2.0).round() as i32
}

impl Fingerprint {
    pub fn of(rtt_s: f64, bandwidth_mbps: f64, avg_file_mb: f64, n_files: u64) -> Fingerprint {
        Fingerprint {
            rtt_bucket: half_octave(rtt_s),
            bw_bucket: half_octave(bandwidth_mbps),
            file_bucket: half_octave(avg_file_mb),
            count_bucket: (n_files as f64 + 1.0).log2().round() as i32,
        }
    }
}

/// A converged tuning decision worth replaying.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CachedTuning {
    /// Converged protocol parameters.
    pub params: Params,
    /// Throughput the knowledge base predicted for them (Mbps).
    pub predicted_mbps: f64,
    /// Index of the load-intensity bucket the ASM converged to —
    /// the warm-start anchor for `online::asm`.
    pub bucket: usize,
}

/// Monotonic counters describing cache effectiveness.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub insertions: u64,
    pub evictions: u64,
}

impl CacheStats {
    /// Fraction of lookups that hit; 0 when no lookups happened.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Fixed-capacity LRU map from [`Fingerprint`] to [`CachedTuning`].
#[derive(Debug)]
pub struct TuningCache {
    cap: usize,
    map: HashMap<Fingerprint, (CachedTuning, u64)>,
    tick: u64,
    stats: CacheStats,
}

impl TuningCache {
    /// `cap` is clamped to at least 1 entry.
    pub fn new(cap: usize) -> TuningCache {
        TuningCache {
            cap: cap.max(1),
            map: HashMap::new(),
            tick: 0,
            stats: CacheStats::default(),
        }
    }

    /// Look up a fingerprint, bumping its recency on hit and counting
    /// the outcome either way.
    pub fn get(&mut self, fp: Fingerprint) -> Option<CachedTuning> {
        self.tick += 1;
        match self.map.get_mut(&fp) {
            Some((tuning, tick)) => {
                *tick = self.tick;
                self.stats.hits += 1;
                Some(*tuning)
            }
            None => {
                self.stats.misses += 1;
                None
            }
        }
    }

    /// Insert or refresh an entry, evicting the least-recently-used
    /// fingerprint when over capacity.  Ties on recency (possible only
    /// across distinct ticks is impossible; ticks are unique) never
    /// arise, so eviction is deterministic.
    pub fn put(&mut self, fp: Fingerprint, tuning: CachedTuning) {
        self.tick += 1;
        let fresh = self.map.insert(fp, (tuning, self.tick)).is_none();
        if fresh {
            self.stats.insertions += 1;
        }
        while self.map.len() > self.cap {
            // O(n) min-tick scan; cap is small by construction.
            let oldest = self
                .map
                .iter()
                .min_by_key(|(_, (_, tick))| *tick)
                .map(|(fp, _)| *fp)
                .expect("non-empty map over capacity");
            self.map.remove(&oldest);
            self.stats.evictions += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn stats(&self) -> CacheStats {
        self.stats
    }

    /// Drop all entries; counters are preserved (they are lifetime
    /// totals, not window totals).
    pub fn clear(&mut self) {
        self.map.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tuning(bucket: usize) -> CachedTuning {
        CachedTuning {
            params: Params::new(4, 2, 8),
            predicted_mbps: 1000.0 + bucket as f64,
            bucket,
        }
    }

    #[test]
    fn fingerprint_buckets_cluster_similar_conditions() {
        let a = Fingerprint::of(0.040, 1000.0, 512.0, 64);
        let b = Fingerprint::of(0.042, 1050.0, 540.0, 70);
        assert_eq!(a, b);
        let far = Fingerprint::of(0.120, 100.0, 8.0, 2000);
        assert_ne!(a, far);
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut cache = TuningCache::new(2);
        let f1 = Fingerprint::of(0.01, 100.0, 10.0, 10);
        let f2 = Fingerprint::of(0.10, 1000.0, 100.0, 100);
        let f3 = Fingerprint::of(1.00, 10000.0, 1000.0, 1000);
        cache.put(f1, tuning(1));
        cache.put(f2, tuning(2));
        // Touch f1 so f2 becomes the LRU entry.
        assert!(cache.get(f1).is_some());
        cache.put(f3, tuning(3));
        assert_eq!(cache.len(), 2);
        assert!(cache.get(f2).is_none(), "LRU entry should be evicted");
        assert!(cache.get(f1).is_some());
        assert!(cache.get(f3).is_some());
        assert_eq!(cache.stats().evictions, 1);
        assert_eq!(cache.stats().insertions, 3);
    }

    #[test]
    fn refresh_does_not_count_as_insertion_or_grow() {
        let mut cache = TuningCache::new(2);
        let f1 = Fingerprint::of(0.01, 100.0, 10.0, 10);
        cache.put(f1, tuning(1));
        cache.put(f1, tuning(9));
        assert_eq!(cache.len(), 1);
        assert_eq!(cache.stats().insertions, 1);
        assert_eq!(cache.get(f1).unwrap().bucket, 9);
    }

    #[test]
    fn hit_rate_counts_lookups() {
        let mut cache = TuningCache::new(4);
        let f1 = Fingerprint::of(0.01, 100.0, 10.0, 10);
        let f2 = Fingerprint::of(0.10, 1000.0, 100.0, 100);
        assert!(cache.get(f1).is_none()); // miss
        cache.put(f1, tuning(1));
        assert!(cache.get(f1).is_some()); // hit
        assert!(cache.get(f1).is_some()); // hit
        assert!(cache.get(f2).is_none()); // miss
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (2, 2));
        assert!((s.hit_rate() - 0.5).abs() < 1e-12);
        assert_eq!(CacheStats::default().hit_rate(), 0.0);
    }

    #[test]
    fn capacity_zero_clamps_to_one() {
        let mut cache = TuningCache::new(0);
        let f1 = Fingerprint::of(0.01, 100.0, 10.0, 10);
        let f2 = Fingerprint::of(0.10, 1000.0, 100.0, 100);
        cache.put(f1, tuning(1));
        cache.put(f2, tuning(2));
        assert_eq!(cache.len(), 1);
    }
}
