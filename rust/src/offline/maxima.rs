//! Surface maxima via the second-partial-derivative test (§4.1.3).
//!
//! Pipeline: dense refinement (the L1 kernel's job on the PJRT path)
//! proposes candidates as refined-grid local maxima; each candidate is
//! polished by a few damped-Newton steps on the analytic spline
//! gradient; the 2×2 Hessian of the (p, cc) slice is then tested for
//! negative definiteness (both eigenvalues < 0).  Domain-boundary
//! maxima — where the gradient need not vanish — are kept and flagged.

use crate::offline::spline::BicubicSurface;
use crate::util::linalg::sym2_eigenvalues;

/// A local maximum of one surface slice.
#[derive(Debug, Clone, Copy)]
pub struct LocalMax {
    pub p: f64,
    pub cc: f64,
    pub value: f64,
    /// Hessian negative definite (true interior max); boundary maxima
    /// carry `false` here and `on_boundary = true`.
    pub neg_definite: bool,
    pub on_boundary: bool,
}

/// Newton-polish an interior candidate; returns the refined point.
fn polish(s: &BicubicSurface, mut p: f64, mut cc: f64) -> (f64, f64) {
    let (plo, phi) = s.p_range();
    let (clo, chi) = s.cc_range();
    for _ in 0..12 {
        let jet = s.eval_with_derivs(p, cc);
        // solve H dx = -grad (2x2)
        let det = jet.fpp_ * jet.fcccc - jet.fpcc * jet.fpcc;
        if det.abs() < 1e-12 {
            break;
        }
        let dp = -(jet.fcccc * jet.fp - jet.fpcc * jet.fcc) / det;
        let dcc = -(jet.fpp_ * jet.fcc - jet.fpcc * jet.fp) / det;
        // damped step, clamped to the domain
        let step = 0.8;
        let np = (p + step * dp).clamp(plo, phi);
        let ncc = (cc + step * dcc).clamp(clo, chi);
        if (np - p).abs() < 1e-9 && (ncc - cc).abs() < 1e-9 {
            p = np;
            cc = ncc;
            break;
        }
        p = np;
        cc = ncc;
    }
    (p, cc)
}

/// All local maxima of a surface found on an `rf`-times-refined grid,
/// sorted by value descending.
pub fn find_local_maxima(s: &BicubicSurface, rf: usize) -> Vec<LocalMax> {
    let dense = s.dense_eval(rf);
    let rows = dense.len();
    let cols = dense[0].len();
    let (plo, phi) = s.p_range();
    let (clo, chi) = s.cc_range();
    let boundary_eps = 1e-6;

    let mut out: Vec<LocalMax> = Vec::new();
    let mut push_candidate = |p0: f64, cc0: f64| {
        let (p, cc) = polish(s, p0, cc0);
        let jet = s.eval_with_derivs(p, cc);
        let (lo, hi) = sym2_eigenvalues(jet.fpp_, jet.fpcc, jet.fcccc);
        let on_boundary = (p - plo).abs() < boundary_eps
            || (p - phi).abs() < boundary_eps
            || (cc - clo).abs() < boundary_eps
            || (cc - chi).abs() < boundary_eps;
        let neg_definite = lo < 0.0 && hi < 0.0;
        if !neg_definite && !on_boundary {
            return; // saddle or minimum: rejected by the Hessian test
        }
        // dedup: merge with an existing max if within half a knot step
        let tol = 0.5;
        for m in &mut out {
            if (m.p - p).abs() < tol && (m.cc - cc).abs() < tol {
                if jet.f > m.value {
                    *m = LocalMax {
                        p,
                        cc,
                        value: jet.f,
                        neg_definite,
                        on_boundary,
                    };
                }
                return;
            }
        }
        out.push(LocalMax {
            p,
            cc,
            value: jet.f,
            neg_definite,
            on_boundary,
        });
    };

    // interior + boundary candidates from the dense refinement; the far
    // boundary row/col is not sampled by the left-closed refinement, so
    // scan knot boundary points explicitly afterwards.
    for i in 0..rows {
        for j in 0..cols {
            let v = dense[i][j];
            let mut is_max = true;
            'nb: for di in -1i64..=1 {
                for dj in -1i64..=1 {
                    if di == 0 && dj == 0 {
                        continue;
                    }
                    let (ni, nj) = (i as i64 + di, j as i64 + dj);
                    if ni < 0 || nj < 0 || ni >= rows as i64 || nj >= cols as i64 {
                        continue;
                    }
                    if dense[ni as usize][nj as usize] > v {
                        is_max = false;
                        break 'nb;
                    }
                }
            }
            if is_max {
                let (p0, cc0) = s.refined_to_coords(i, j, rf);
                push_candidate(p0, cc0);
            }
        }
    }
    // far edges
    for &p0 in s.xs.iter() {
        push_candidate(p0, chi);
    }
    for &cc0 in s.ys.iter() {
        push_candidate(phi, cc0);
    }

    out.sort_by(|a, b| b.value.total_cmp(&a.value));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offline::surface::knot_lattice;

    fn fit_fn<F: Fn(f64, f64) -> f64>(f: F) -> BicubicSurface {
        let xs = knot_lattice();
        let values: Vec<Vec<f64>> = xs
            .iter()
            .map(|&p| xs.iter().map(|&cc| f(p, cc)).collect())
            .collect();
        BicubicSurface::fit(&xs, &xs, &values)
    }

    #[test]
    fn single_interior_peak() {
        let s = fit_fn(|p, cc| 1_000.0 - (p - 10.0).powi(2) * 4.0 - (cc - 12.0).powi(2) * 3.0);
        let maxima = find_local_maxima(&s, 8);
        assert!(!maxima.is_empty());
        let top = &maxima[0];
        assert!((top.p - 10.0).abs() < 1.0, "p={}", top.p);
        assert!((top.cc - 12.0).abs() < 1.0, "cc={}", top.cc);
        assert!(top.neg_definite, "interior peak must pass the Hessian test");
        assert!(!top.on_boundary);
    }

    #[test]
    fn monotone_surface_max_on_boundary() {
        let s = fit_fn(|p, cc| 3.0 * p + 2.0 * cc);
        let maxima = find_local_maxima(&s, 8);
        let top = &maxima[0];
        assert!(top.on_boundary);
        assert!((top.p - 32.0).abs() < 1e-6 && (top.cc - 32.0).abs() < 1e-6);
        assert!((top.value - (3.0 * 32.0 + 2.0 * 32.0)).abs() < 1e-6);
    }

    #[test]
    fn two_bumps_found() {
        let s = fit_fn(|p, cc| {
            let b1 = 800.0 * (-(p - 4.0).powi(2) / 8.0 - (cc - 4.0).powi(2) / 8.0).exp();
            let b2 = 600.0 * (-(p - 24.0).powi(2) / 32.0 - (cc - 24.0).powi(2) / 32.0).exp();
            b1 + b2
        });
        let maxima = find_local_maxima(&s, 8);
        let interior: Vec<&LocalMax> = maxima.iter().filter(|m| m.neg_definite).collect();
        assert!(interior.len() >= 2, "found {} interior maxima", interior.len());
        // the two bump locations
        assert!(interior.iter().any(|m| (m.p - 4.0).abs() < 2.0));
        assert!(interior.iter().any(|m| (m.p - 24.0).abs() < 4.0));
        // sorted descending
        assert!(maxima.windows(2).all(|w| w[0].value >= w[1].value));
    }

    #[test]
    fn saddle_is_rejected() {
        // f = (p-10)^2 - (cc-10)^2 has a saddle at (10, 10); the only
        // maxima live on the boundary
        let s = fit_fn(|p, cc| (p - 10.0).powi(2) - (cc - 10.0).powi(2));
        let maxima = find_local_maxima(&s, 8);
        for m in &maxima {
            assert!(
                m.on_boundary || (m.p - 10.0).abs() > 1.0 || (m.cc - 10.0).abs() > 1.0,
                "saddle leaked through: {m:?}"
            );
        }
    }

    #[test]
    fn newton_polish_beats_grid_resolution() {
        // peak at p = 9.37, cc = 7.21 — off both the knot grid and the
        // rf=4 refinement lattice
        let s = fit_fn(|p, cc| -(p - 9.37).powi(2) - (cc - 7.21).powi(2));
        let maxima = find_local_maxima(&s, 4);
        let top = &maxima[0];
        assert!(
            (top.p - 9.37).abs() < 0.3 && (top.cc - 7.21).abs() < 0.3,
            "polish failed: ({}, {})",
            top.p,
            top.cc
        );
    }
}
