//! Gaussian confidence regions around fitted surfaces (Eq 12–14).
//!
//! Repeated observations at the same parameter point scatter around the
//! surface (measurement error, route changes, minor queueing — Fig 4a);
//! the paper wraps each surface in a Gaussian band.  The online phase
//! asks one question: *is this achieved throughput consistent with this
//! surface?* — answered by [`ConfidenceRegion::contains`].

use crate::util::stats;

/// Gaussian band around a surface.
#[derive(Debug, Clone, PartialEq)]
pub struct ConfidenceRegion {
    /// Residual standard deviation σ of observations vs the fit.
    pub sigma: f64,
    /// z multiplier for the acceptance band (paper checks whether the
    /// sample lies "inside the surface confidence bound").
    pub z: f64,
}

impl ConfidenceRegion {
    /// Build from fit residuals (observed − predicted).  A floor keeps
    /// the band usable when replication is thin: relative_floor scales
    /// with the surface magnitude.
    pub fn from_residuals(residuals: &[f64], surface_scale: f64, z: f64) -> ConfidenceRegion {
        let sigma_raw = stats::std_pop(residuals);
        // At least 4% of the surface magnitude: the simulator's sampling
        // noise alone is ~5% lognormal, and a zero-width band would
        // reject every future sample.
        let sigma = sigma_raw.max(0.04 * surface_scale.abs());
        ConfidenceRegion { sigma, z }
    }

    /// Is an achieved throughput consistent with a predicted value?
    pub fn contains(&self, predicted: f64, achieved: f64) -> bool {
        (achieved - predicted).abs() <= self.z * self.sigma
    }

    /// Signed deviation in σ units (positive = achieved above surface).
    pub fn deviation_sigmas(&self, predicted: f64, achieved: f64) -> f64 {
        (achieved - predicted) / self.sigma
    }

    pub fn band(&self) -> f64 {
        self.z * self.sigma
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn sigma_estimates_noise() {
        let mut rng = Rng::new(4);
        let residuals: Vec<f64> = (0..5_000).map(|_| rng.normal_ms(0.0, 25.0)).collect();
        let c = ConfidenceRegion::from_residuals(&residuals, 100.0, 2.0);
        assert!((c.sigma - 25.0).abs() < 2.0, "sigma={}", c.sigma);
    }

    #[test]
    fn floor_applies_when_replication_thin() {
        let c = ConfidenceRegion::from_residuals(&[0.0], 1_000.0, 2.0);
        assert!((c.sigma - 40.0).abs() < 1e-9);
    }

    #[test]
    fn contains_is_symmetric_band() {
        let c = ConfidenceRegion {
            sigma: 10.0,
            z: 2.0,
        };
        assert!(c.contains(100.0, 119.9));
        assert!(c.contains(100.0, 80.1));
        assert!(!c.contains(100.0, 121.0));
        assert!(!c.contains(100.0, 79.0));
    }

    #[test]
    fn coverage_near_nominal() {
        // ~95% of Gaussian samples must fall inside a z=1.96 band
        let mut rng = Rng::new(8);
        let c = ConfidenceRegion {
            sigma: 10.0,
            z: 1.96,
        };
        let n = 20_000;
        let inside = (0..n)
            .filter(|_| c.contains(500.0, rng.normal_ms(500.0, 10.0)))
            .count();
        let frac = inside as f64 / n as f64;
        assert!((frac - 0.95).abs() < 0.01, "coverage={frac}");
    }

    #[test]
    fn deviation_sign() {
        let c = ConfidenceRegion {
            sigma: 5.0,
            z: 2.0,
        };
        assert!(c.deviation_sigmas(100.0, 110.0) > 0.0);
        assert!(c.deviation_sigmas(100.0, 90.0) < 0.0);
        assert_eq!(c.deviation_sigmas(100.0, 100.0), 0.0);
    }
}
