//! Suitable sampling regions `R_s = R_m ∪ R_c` (§4.1.4, Eq 17–19).
//!
//! * `R_m`: neighborhoods of radius `r_d` around every surface's
//!   maxima — where the payoff lives;
//! * `R_c`: the γ-point uniform sample ranked by the max–min surface
//!   separation `Δ_min(u) = min_{i≠j} |f_i(u) − f_j(u)|` (Eq 18),
//!   keeping the λ most *distinguishing* points — sampling there tells
//!   the online phase which load surface it is on fastest.

use crate::offline::surface::ThroughputSurface;
use crate::util::rng::Rng;
use crate::Params;

/// A candidate sample point with its provenance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplePoint {
    pub params: Params,
    /// Δ_min separation score (0 for R_m members, Eq 18 value for R_c).
    pub separation: f64,
    pub from_maxima: bool,
}

/// Configuration for region extraction.
#[derive(Debug, Clone)]
pub struct RegionConfig {
    /// neighborhood radius around maxima, in parameter units (r_d)
    pub r_d: f64,
    /// uniform sample size (γ)
    pub gamma: usize,
    /// how many top-separation points to keep (λ)
    pub lambda: usize,
    pub seed: u64,
}

impl Default for RegionConfig {
    fn default() -> Self {
        RegionConfig {
            r_d: 2.0,
            gamma: 256,
            lambda: 8,
            seed: 0x5247,
        }
    }
}

fn clamp_param(v: f64, lo: f64, hi: f64) -> u32 {
    (v.round().clamp(lo, hi)) as u32
}

/// Extract `R_s` for a set of same-cluster surfaces (any mix of load
/// buckets / pp slices).  Deduplicated on integer parameters.
pub fn suitable_regions(surfaces: &[ThroughputSurface], cfg: &RegionConfig) -> Vec<SamplePoint> {
    let mut out: Vec<SamplePoint> = Vec::new();
    if surfaces.is_empty() {
        return out;
    }
    let (plo, phi) = surfaces[0].fitted.surface.p_range();
    let (clo, chi) = surfaces[0].fitted.surface.cc_range();

    let mut push = |pt: SamplePoint| {
        if !out.iter().any(|q| q.params == pt.params) {
            out.push(pt);
        }
    };

    // R_m: maxima neighborhoods (center + r_d-offset cross)
    for s in surfaces {
        let (mp, mcc) = s.fitted.max_at;
        let offsets = [
            (0.0, 0.0),
            (cfg.r_d, 0.0),
            (-cfg.r_d, 0.0),
            (0.0, cfg.r_d),
            (0.0, -cfg.r_d),
        ];
        for (dp, dcc) in offsets {
            push(SamplePoint {
                params: Params::new(
                    clamp_param(mcc + dcc, clo, chi),
                    clamp_param(mp + dp, plo, phi),
                    s.pp,
                ),
                separation: 0.0,
                from_maxima: true,
            });
        }
    }

    // R_c: Eq 17-18 uniform sample ranked by Δ_min
    if surfaces.len() >= 2 {
        let mut rng = Rng::new(cfg.seed);
        let mut scored: Vec<SamplePoint> = Vec::with_capacity(cfg.gamma);
        for _ in 0..cfg.gamma {
            let p = rng.uniform(plo, phi);
            let cc = rng.uniform(clo, chi);
            // Δ_min over all surface pairs at this coordinate
            let vals: Vec<f64> = surfaces
                .iter()
                .map(|s| s.fitted.surface.eval(p, cc))
                .collect();
            let mut dmin = f64::INFINITY;
            for i in 0..vals.len() {
                for j in i + 1..vals.len() {
                    dmin = dmin.min((vals[i] - vals[j]).abs());
                }
            }
            // the pp of the surface whose value is largest here: the
            // most informative slice to actually transfer with
            let best_slice = surfaces
                .iter()
                .zip(&vals)
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(s, _)| s.pp)
                .unwrap_or(surfaces[0].pp);
            scored.push(SamplePoint {
                params: Params::new(
                    clamp_param(cc, clo, chi),
                    clamp_param(p, plo, phi),
                    best_slice,
                ),
                separation: dmin,
                from_maxima: false,
            });
        }
        scored.sort_by(|a, b| b.separation.total_cmp(&a.separation));
        for pt in scored.into_iter().take(cfg.lambda) {
            push(pt);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offline::confidence::ConfidenceRegion;
    use crate::offline::spline::BicubicSurface;
    use crate::offline::surface::{knot_lattice, FittedSurface};

    fn surface_from_fn<F: Fn(f64, f64) -> f64>(
        f: F,
        pp: u32,
        bucket: usize,
        max_at: (f64, f64),
    ) -> ThroughputSurface {
        let xs = knot_lattice();
        let values: Vec<Vec<f64>> = xs
            .iter()
            .map(|&p| xs.iter().map(|&cc| f(p, cc)).collect())
            .collect();
        let surface = BicubicSurface::fit(&xs, &xs, &values);
        let max_th = f(max_at.0, max_at.1);
        ThroughputSurface {
            pp,
            load_bucket: bucket,
            load_intensity: bucket as f64 / 4.0,
            fitted: FittedSurface {
                surface,
                max_th,
                max_at,
                grid_mean: 0.0,
                grid_std: 1.0,
            },
            confidence: ConfidenceRegion {
                sigma: 10.0,
                z: 2.0,
            },
            optimal_params: Params::new(max_at.1 as u32, max_at.0 as u32, pp),
            optimal_th: max_th,
            n_obs: 64,
            coverage: 1.0,
        }
    }

    fn two_surfaces() -> Vec<ThroughputSurface> {
        vec![
            // far apart at high (p, cc), identical near the origin
            surface_from_fn(|p, cc| p * cc, 4, 0, (32.0, 32.0)),
            surface_from_fn(|p, cc| 0.25 * p * cc, 4, 3, (32.0, 32.0)),
        ]
    }

    #[test]
    fn includes_maxima_neighborhoods() {
        let ss = two_surfaces();
        let pts = suitable_regions(&ss, &RegionConfig::default());
        // the shared maximum (32, 32) must be present
        assert!(pts
            .iter()
            .any(|q| q.from_maxima && q.params.p == 32 && q.params.cc == 32));
        // and its r_d = 2 neighborhood
        assert!(pts.iter().any(|q| q.from_maxima && q.params.p == 30));
    }

    #[test]
    fn separation_points_prefer_distinguishing_regions() {
        let ss = two_surfaces();
        let cfg = RegionConfig::default();
        let pts = suitable_regions(&ss, &cfg);
        let rc: Vec<&SamplePoint> = pts.iter().filter(|q| !q.from_maxima).collect();
        assert!(!rc.is_empty());
        // |f1 - f2| = 0.75 p·cc grows with p·cc: the kept points must
        // skew towards the high-product corner
        let mean_product: f64 = rc
            .iter()
            .map(|q| q.params.p as f64 * q.params.cc as f64)
            .sum::<f64>()
            / rc.len() as f64;
        assert!(mean_product > 300.0, "mean p*cc = {mean_product}");
        // scores must be sorted-ish: all kept scores above the typical
        for q in &rc {
            assert!(q.separation > 0.0);
        }
    }

    #[test]
    fn no_duplicate_parameter_points() {
        let ss = two_surfaces();
        let pts = suitable_regions(&ss, &RegionConfig::default());
        for (i, a) in pts.iter().enumerate() {
            for b in pts.iter().skip(i + 1) {
                assert_ne!(a.params, b.params);
            }
        }
    }

    #[test]
    fn single_surface_yields_only_maxima_region() {
        let ss = vec![surface_from_fn(|p, cc| p + cc, 8, 1, (32.0, 32.0))];
        let pts = suitable_regions(&ss, &RegionConfig::default());
        assert!(pts.iter().all(|q| q.from_maxima));
        assert!(!pts.is_empty());
    }

    #[test]
    fn empty_input() {
        assert!(suitable_regions(&[], &RegionConfig::default()).is_empty());
    }

    #[test]
    fn params_stay_in_domain() {
        let ss = two_surfaces();
        let pts = suitable_regions(&ss, &RegionConfig::default());
        for q in &pts {
            assert!((1..=32).contains(&q.params.p));
            assert!((1..=32).contains(&q.params.cc));
        }
    }
}
