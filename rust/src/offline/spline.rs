//! Natural cubic splines and tensor-product bicubic surfaces — the
//! native mirror of the L2 JAX graphs in `python/compile/model.py`
//! (same construction, same normalized-local-coordinate coefficient
//! layout `k = 4a + b` for `u^a v^b`), parity-tested against the PJRT
//! artifacts in `rust/tests/integration_runtime.rs`.

use crate::util::linalg::thomas;
use crate::util::par;

/// 1-D natural cubic spline through (xs, ys).
#[derive(Debug, Clone, PartialEq)]
pub struct Spline1D {
    pub xs: Vec<f64>,
    /// per-interval coefficients in normalized local coords:
    /// g_i(u) = c0 + c1 u + c2 u² + c3 u³, u = (x − xs[i]) / h_i
    pub coeffs: Vec<[f64; 4]>,
}

/// Second derivatives M_i of the natural cubic spline (M_0 = M_n = 0).
pub fn natural_spline_m(xs: &[f64], ys: &[f64]) -> Vec<f64> {
    let n = xs.len();
    assert_eq!(n, ys.len());
    assert!(n >= 2, "need at least 2 knots");
    let mut m = vec![0.0; n];
    if n == 2 {
        return m;
    }
    let h: Vec<f64> = xs.windows(2).map(|w| w[1] - w[0]).collect();
    let k = n - 2;
    let mut sub = vec![0.0; k];
    let mut diag = vec![0.0; k];
    let mut sup = vec![0.0; k];
    let mut rhs = vec![0.0; k];
    for i in 0..k {
        sub[i] = h[i] / 6.0;
        diag[i] = (h[i] + h[i + 1]) / 3.0;
        sup[i] = h[i + 1] / 6.0;
        rhs[i] = (ys[i + 2] - ys[i + 1]) / h[i + 1] - (ys[i + 1] - ys[i]) / h[i];
    }
    let sol = thomas(&sub, &diag, &sup, &rhs).expect("spline system is SPD");
    m[1..=k].copy_from_slice(&sol);
    m
}

impl Spline1D {
    /// Fit through strictly increasing knots.
    pub fn fit(xs: &[f64], ys: &[f64]) -> Spline1D {
        assert!(
            xs.windows(2).all(|w| w[1] > w[0]),
            "knots must be strictly increasing"
        );
        let m = natural_spline_m(xs, ys);
        let n = xs.len();
        let mut coeffs = Vec::with_capacity(n - 1);
        for i in 0..n - 1 {
            let h = xs[i + 1] - xs[i];
            let a0 = ys[i];
            let a1 = (ys[i + 1] - ys[i]) / h - h * (2.0 * m[i] + m[i + 1]) / 6.0;
            let a2 = m[i] / 2.0;
            let a3 = (m[i + 1] - m[i]) / (6.0 * h);
            coeffs.push([a0, a1 * h, a2 * h * h, a3 * h * h * h]);
        }
        Spline1D {
            xs: xs.to_vec(),
            coeffs,
        }
    }

    /// Interval index for x (clamped to the domain).
    fn interval(&self, x: f64) -> usize {
        let n = self.xs.len();
        match self.xs.binary_search_by(|k| k.total_cmp(&x)) {
            Ok(i) => i.min(n - 2),
            Err(i) => i.saturating_sub(1).min(n - 2),
        }
    }

    /// Evaluate (clamped extrapolation at the boundary intervals).
    pub fn eval(&self, x: f64) -> f64 {
        let i = self.interval(x);
        let h = self.xs[i + 1] - self.xs[i];
        let u = (x - self.xs[i]) / h;
        let c = &self.coeffs[i];
        c[0] + u * (c[1] + u * (c[2] + u * c[3]))
    }
}

/// Tensor-product natural bicubic surface over a (p, cc) knot grid.
#[derive(Debug, Clone, PartialEq)]
pub struct BicubicSurface {
    /// knots along the first axis (p)
    pub xs: Vec<f64>,
    /// knots along the second axis (cc)
    pub ys: Vec<f64>,
    /// patch coefficients [GP-1][GC-1][16], k = 4a+b for u^a v^b
    pub coeffs: Vec<Vec<[f64; 16]>>,
}

impl BicubicSurface {
    /// Fit from grid values `values[i][j] = f(xs[i], ys[j])`
    /// (spline-of-splines; identical to `compile.model.fit_bicubic`).
    pub fn fit(xs: &[f64], ys: &[f64], values: &[Vec<f64>]) -> BicubicSurface {
        let gp = xs.len();
        let gc = ys.len();
        assert!(gp >= 2 && gc >= 2);
        assert_eq!(values.len(), gp);
        assert!(values.iter().all(|r| r.len() == gc), "ragged value grid");

        // 1) spline along cc for every row (rows are independent;
        //    fanned out over the pool): row_coeffs[i][j][b]
        let row_coeffs: Vec<Vec<[f64; 4]>> =
            par::par_map(values, |_, row| Spline1D::fit(ys, row).coeffs);
        // 2) spline along p of each row coefficient: for every (j, b)
        let mut coeffs = vec![vec![[0.0f64; 16]; gc - 1]; gp - 1];
        let mut samples = vec![0.0; gp];
        for j in 0..gc - 1 {
            for b in 0..4 {
                for i in 0..gp {
                    samples[i] = row_coeffs[i][j][b];
                }
                let s = Spline1D::fit(xs, &samples);
                for i in 0..gp - 1 {
                    for a in 0..4 {
                        coeffs[i][j][4 * a + b] = s.coeffs[i][a];
                    }
                }
            }
        }
        BicubicSurface {
            xs: xs.to_vec(),
            ys: ys.to_vec(),
            coeffs,
        }
    }

    /// Knot-domain extent along the first (p) axis.  `fit` asserts at
    /// least two knots, so the degenerate arm only guards hand-built
    /// surfaces.
    pub fn p_range(&self) -> (f64, f64) {
        match (self.xs.first(), self.xs.last()) {
            (Some(&lo), Some(&hi)) => (lo, hi),
            _ => (1.0, 1.0),
        }
    }

    /// Knot-domain extent along the second (cc) axis.
    pub fn cc_range(&self) -> (f64, f64) {
        match (self.ys.first(), self.ys.last()) {
            (Some(&lo), Some(&hi)) => (lo, hi),
            _ => (1.0, 1.0),
        }
    }

    fn locate(knots: &[f64], x: f64) -> usize {
        let n = knots.len();
        match knots.binary_search_by(|k| k.total_cmp(&x)) {
            Ok(i) => i.min(n - 2),
            Err(i) => i.saturating_sub(1).min(n - 2),
        }
    }

    /// Evaluate at (p, cc), clamped to the knot domain.
    pub fn eval(&self, p: f64, cc: f64) -> f64 {
        let (i, j, u, v) = self.local(p, cc);
        let c = &self.coeffs[i][j];
        let mut acc = 0.0;
        let mut up = 1.0;
        for a in 0..4 {
            let mut vp = 1.0;
            for b in 0..4 {
                acc += c[4 * a + b] * up * vp;
                vp *= v;
            }
            up *= u;
        }
        acc
    }

    fn local(&self, p: f64, cc: f64) -> (usize, usize, f64, f64) {
        let i = Self::locate(&self.xs, p);
        let j = Self::locate(&self.ys, cc);
        let hu = self.xs[i + 1] - self.xs[i];
        let hv = self.ys[j + 1] - self.ys[j];
        let u = (p - self.xs[i]) / hu;
        let v = (cc - self.ys[j]) / hv;
        (i, j, u, v)
    }

    /// Value, gradient and Hessian at (p, cc) in *knot units* (the
    /// normalized-local derivatives rescaled by the patch sizes), for
    /// the second-partial-derivative maxima test.
    pub fn eval_with_derivs(&self, p: f64, cc: f64) -> SurfaceJet {
        let (i, j, u, v) = self.local(p, cc);
        let hu = self.xs[i + 1] - self.xs[i];
        let hv = self.ys[j + 1] - self.ys[j];
        let c = &self.coeffs[i][j];
        let (mut f, mut fu, mut fv, mut fuu, mut fuv, mut fvv) =
            (0.0, 0.0, 0.0, 0.0, 0.0, 0.0);
        let upow = [1.0, u, u * u, u * u * u];
        let vpow = [1.0, v, v * v, v * v * v];
        for a in 0..4usize {
            for b in 0..4usize {
                let cab = c[4 * a + b];
                f += cab * upow[a] * vpow[b];
                if a >= 1 {
                    fu += cab * a as f64 * upow[a - 1] * vpow[b];
                }
                if b >= 1 {
                    fv += cab * b as f64 * upow[a] * vpow[b - 1];
                }
                if a >= 2 {
                    fuu += cab * (a * (a - 1)) as f64 * upow[a - 2] * vpow[b];
                }
                if a >= 1 && b >= 1 {
                    fuv += cab * (a * b) as f64 * upow[a - 1] * vpow[b - 1];
                }
                if b >= 2 {
                    fvv += cab * (b * (b - 1)) as f64 * upow[a] * vpow[b - 2];
                }
            }
        }
        SurfaceJet {
            f,
            fp: fu / hu,
            fcc: fv / hv,
            fpp_: fuu / (hu * hu),
            fpcc: fuv / (hu * hv),
            fcccc: fvv / (hv * hv),
        }
    }

    /// Dense left-closed refinement: out[(gp-1)·rf][(gc-1)·rf] matching
    /// the L1 Pallas kernel's sampling exactly.
    pub fn dense_eval(&self, rf: usize) -> Vec<Vec<f64>> {
        let gp1 = self.coeffs.len();
        let gc1 = self.coeffs[0].len();
        // Each patch row yields rf output rows independently of the
        // others; fan the rows out and flatten in patch order (every
        // cell is computed in isolation, so the result is trivially
        // thread-invariant).
        let patch_rows: Vec<usize> = (0..gp1).collect();
        let blocks = par::par_map(&patch_rows, |_, &i| {
            let mut rows = vec![vec![0.0; gc1 * rf]; rf];
            for (qi, out_row) in rows.iter_mut().enumerate() {
                let u = qi as f64 / rf as f64;
                let upow = [1.0, u, u * u, u * u * u];
                for j in 0..gc1 {
                    let c = &self.coeffs[i][j];
                    for qj in 0..rf {
                        let v = qj as f64 / rf as f64;
                        let vpow = [1.0, v, v * v, v * v * v];
                        let mut acc = 0.0;
                        for a in 0..4 {
                            for b in 0..4 {
                                acc += c[4 * a + b] * upow[a] * vpow[b];
                            }
                        }
                        out_row[j * rf + qj] = acc;
                    }
                }
            }
            rows
        });
        let mut out = Vec::with_capacity(gp1 * rf);
        for b in blocks {
            out.extend(b);
        }
        out
    }

    /// Refined-grid coordinate → (p, cc) in knot units.
    pub fn refined_to_coords(&self, i: usize, j: usize, rf: usize) -> (f64, f64) {
        let pi = i / rf;
        let pj = j / rf;
        let u = (i % rf) as f64 / rf as f64;
        let v = (j % rf) as f64 / rf as f64;
        let p = self.xs[pi] + u * (self.xs[pi + 1] - self.xs[pi]);
        let cc = self.ys[pj] + v * (self.ys[pj + 1] - self.ys[pj]);
        (p, cc)
    }
}

/// Value + first/second derivatives of a surface at a point.
#[derive(Debug, Clone, Copy)]
pub struct SurfaceJet {
    pub f: f64,
    pub fp: f64,
    pub fcc: f64,
    pub fpp_: f64,
    pub fpcc: f64,
    pub fcccc: f64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    #[test]
    fn spline1d_interpolates_knots() {
        let xs = [1.0, 2.0, 4.0, 7.0];
        let ys = [3.0, -1.0, 2.0, 0.5];
        let s = Spline1D::fit(&xs, &ys);
        for (x, y) in xs.iter().zip(&ys) {
            assert!((s.eval(*x) - y).abs() < 1e-10, "at {x}");
        }
    }

    #[test]
    fn spline1d_reproduces_line_exactly() {
        let xs = [0.0, 1.0, 3.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + 1.0).collect();
        let s = Spline1D::fit(&xs, &ys);
        for x in [0.25, 0.5, 1.7, 3.9] {
            assert!((s.eval(x) - (2.0 * x + 1.0)).abs() < 1e-10);
        }
    }

    #[test]
    fn spline1d_c2_continuity_at_knots() {
        let xs = [0.0, 1.0, 2.5, 3.0, 5.0];
        let ys = [1.0, 3.0, -2.0, 0.0, 4.0];
        let s = Spline1D::fit(&xs, &ys);
        // numerical second derivative continuity at interior knots
        let d2 = |x: f64| {
            let h = 1e-4;
            (s.eval(x - h) - 2.0 * s.eval(x) + s.eval(x + h)) / (h * h)
        };
        for &k in &xs[1..4] {
            let left = d2(k - 1e-3);
            let right = d2(k + 1e-3);
            assert!(
                (left - right).abs() < 0.3,
                "kink at {k}: {left} vs {right}"
            );
        }
    }

    #[test]
    fn bicubic_interpolates_grid() {
        let xs = [1.0, 2.0, 4.0, 8.0];
        let ys = [1.0, 3.0, 5.0];
        let values = vec![
            vec![1.0, 2.0, 3.0],
            vec![2.0, 5.0, 4.0],
            vec![3.0, 7.0, 6.0],
            vec![2.0, 4.0, 9.0],
        ];
        let s = BicubicSurface::fit(&xs, &ys, &values);
        for (i, &x) in xs.iter().enumerate() {
            for (j, &y) in ys.iter().enumerate() {
                assert!(
                    (s.eval(x, y) - values[i][j]).abs() < 1e-9,
                    "at ({x},{y})"
                );
            }
        }
    }

    #[test]
    fn bicubic_reproduces_bilinear_product() {
        let xs = [1.0, 2.0, 4.0, 8.0];
        let ys = [1.0, 3.0, 5.0];
        let values: Vec<Vec<f64>> = xs
            .iter()
            .map(|&x| ys.iter().map(|&y| x * y).collect())
            .collect();
        let s = BicubicSurface::fit(&xs, &ys, &values);
        for p in [1.0, 1.5, 3.3, 6.2, 8.0] {
            for cc in [1.0, 2.1, 4.9] {
                assert!((s.eval(p, cc) - p * cc).abs() < 1e-9, "at ({p},{cc})");
            }
        }
    }

    #[test]
    fn derivs_match_finite_differences() {
        let xs = [1.0, 2.0, 4.0, 8.0];
        let ys = [1.0, 3.0, 5.0, 9.0];
        let values = vec![
            vec![1.0, 2.0, 3.0, 1.0],
            vec![2.0, 6.0, 4.0, 2.0],
            vec![3.0, 7.0, 8.0, 3.0],
            vec![2.0, 4.0, 5.0, 1.0],
        ];
        let s = BicubicSurface::fit(&xs, &ys, &values);
        let (p, cc) = (3.0, 4.0);
        let jet = s.eval_with_derivs(p, cc);
        let h = 1e-5;
        let fp = (s.eval(p + h, cc) - s.eval(p - h, cc)) / (2.0 * h);
        let fcc = (s.eval(p, cc + h) - s.eval(p, cc - h)) / (2.0 * h);
        let fpp = (s.eval(p + h, cc) - 2.0 * jet.f + s.eval(p - h, cc)) / (h * h);
        assert!((jet.f - s.eval(p, cc)).abs() < 1e-12);
        assert!((jet.fp - fp).abs() < 1e-5, "{} vs {fp}", jet.fp);
        assert!((jet.fcc - fcc).abs() < 1e-5);
        assert!((jet.fpp_ - fpp).abs() < 1e-3);
    }

    #[test]
    fn dense_eval_matches_pointwise_eval() {
        let xs = [1.0, 2.0, 4.0];
        let ys = [1.0, 3.0, 5.0];
        let values = vec![
            vec![1.0, 2.0, 3.0],
            vec![2.0, 6.0, 4.0],
            vec![3.0, 7.0, 8.0],
        ];
        let s = BicubicSurface::fit(&xs, &ys, &values);
        let rf = 4;
        let dense = s.dense_eval(rf);
        for i in 0..dense.len() {
            for j in 0..dense[0].len() {
                let (p, cc) = s.refined_to_coords(i, j, rf);
                assert!(
                    (dense[i][j] - s.eval(p, cc)).abs() < 1e-10,
                    "mismatch at refined ({i},{j})"
                );
            }
        }
    }

    #[test]
    fn prop_interpolation_and_boundedness() {
        prop::run("bicubic interpolates random grids", 40, |g| {
            let gp = g.usize_in(3..=7);
            let gc = g.usize_in(3..=7);
            let xs = g.knots(gp);
            let ys = g.knots(gc);
            let values: Vec<Vec<f64>> = (0..gp)
                .map(|_| (0..gc).map(|_| g.f64_in(0.0..100.0)).collect())
                .collect();
            let s = BicubicSurface::fit(&xs, &ys, &values);
            for i in 0..gp {
                for j in 0..gc {
                    let got = s.eval(xs[i], ys[j]);
                    assert!(
                        (got - values[i][j]).abs() < 1e-7,
                        "knot ({i},{j}): {got} vs {}",
                        values[i][j]
                    );
                }
            }
        });
    }
}
