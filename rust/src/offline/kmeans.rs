//! K-means++ clustering (Arthur & Vassilvitskii 2007) — the paper's
//! first clustering option (§4.1.1), chosen for its O(log m)
//! competitiveness guarantee over plain K-means initialization.
//!
//! Native Lloyd iterations here; the PJRT-accelerated assignment step
//! (Pallas pairwise-distance kernel) plugs in via
//! `runtime::accel::PjrtKmeans`, parity-tested in the integration
//! suite.

use crate::offline::features::{sqdist, N_FEATURES};
use crate::util::par;
use crate::util::rng::Rng;

/// Result of one clustering run.
#[derive(Debug, Clone)]
pub struct Clustering {
    pub centroids: Vec<[f64; N_FEATURES]>,
    pub assignment: Vec<usize>,
    pub inertia: f64,
}

/// One Lloyd step implemented by a backend (native or PJRT).
pub trait KmeansBackend: Sync {
    /// Returns (new centroids, assignment, inertia).  Empty clusters
    /// are reseeded from the points farthest from their assigned
    /// centroids (see [`reseed_empty_clusters`]) instead of keeping a
    /// stale centroid.
    fn step(
        &self,
        points: &[[f64; N_FEATURES]],
        centroids: &[[f64; N_FEATURES]],
    ) -> (Vec<[f64; N_FEATURES]>, Vec<usize>, f64);
}

/// Reseed empty clusters from the points farthest from their assigned
/// centroids: the e-th empty cluster takes the (e+1)-th farthest point
/// (ties break to the lowest point index) — a deterministic variant of
/// the classic "split the worst-fit point" repair.  Keeping the stale
/// centroid instead (the previous behaviour) left dead clusters
/// stranded forever on small-n fixtures.  Shared by the native and
/// PJRT backends so their steps stay in parity.
///
/// `d2[i]` is the squared distance of point `i` to its assigned (old)
/// centroid; `counts[ci]` the number of points assigned to cluster `ci`.
pub fn reseed_empty_clusters(
    points: &[[f64; N_FEATURES]],
    d2: &[f64],
    counts: &[usize],
    centroids: &mut [[f64; N_FEATURES]],
) {
    let empties: Vec<usize> = counts
        .iter()
        .enumerate()
        .filter(|&(_, &c)| c == 0)
        .map(|(ci, _)| ci)
        .collect();
    if empties.is_empty() || points.is_empty() {
        return;
    }
    let mut order: Vec<usize> = (0..points.len()).collect();
    order.sort_by(|&a, &b| d2[b].total_cmp(&d2[a]).then(a.cmp(&b)));
    for (e, &ci) in empties.iter().enumerate() {
        if e < order.len() {
            centroids[ci] = points[order[e]];
        }
    }
}

/// Fixed chunk size for the parallel assignment scan.  Boundaries
/// depend only on this constant — never on the thread count — so the
/// per-chunk partials reduce in identical floating-point order for any
/// `PALLAS_THREADS` setting (including 1).
const STEP_CHUNK: usize = 512;

/// Per-chunk partial of one Lloyd step.
struct StepPartial {
    assignment: Vec<usize>,
    d2: Vec<f64>,
    inertia: f64,
    sums: Vec<[f64; N_FEATURES]>,
    counts: Vec<usize>,
}

/// Plain-Rust backend.
pub struct NativeKmeans;

impl KmeansBackend for NativeKmeans {
    fn step(
        &self,
        points: &[[f64; N_FEATURES]],
        centroids: &[[f64; N_FEATURES]],
    ) -> (Vec<[f64; N_FEATURES]>, Vec<usize>, f64) {
        let k = centroids.len();
        let windows: Vec<&[[f64; N_FEATURES]]> = points.chunks(STEP_CHUNK).collect();
        let partials = par::par_map(&windows, |_, w| {
            let mut part = StepPartial {
                assignment: Vec::with_capacity(w.len()),
                d2: Vec::with_capacity(w.len()),
                inertia: 0.0,
                sums: vec![[0.0; N_FEATURES]; k],
                counts: vec![0usize; k],
            };
            for p in w.iter() {
                let mut best = (0usize, f64::INFINITY);
                for (ci, c) in centroids.iter().enumerate() {
                    let d = sqdist(p, c);
                    if d < best.1 {
                        best = (ci, d);
                    }
                }
                part.assignment.push(best.0);
                part.d2.push(best.1);
                part.inertia += best.1;
                part.counts[best.0] += 1;
                for f in 0..N_FEATURES {
                    part.sums[best.0][f] += p[f];
                }
            }
            part
        });
        // In-order reduction: chunk order is fixed, so the summation
        // order (and hence every bit of the result) is thread-invariant.
        let mut assignment = Vec::with_capacity(points.len());
        let mut d2 = Vec::with_capacity(points.len());
        let mut inertia = 0.0;
        let mut sums = vec![[0.0; N_FEATURES]; k];
        let mut counts = vec![0usize; k];
        for part in partials {
            assignment.extend(part.assignment);
            d2.extend(part.d2);
            inertia += part.inertia;
            for ci in 0..k {
                counts[ci] += part.counts[ci];
                for f in 0..N_FEATURES {
                    sums[ci][f] += part.sums[ci][f];
                }
            }
        }
        let mut new_centroids: Vec<[f64; N_FEATURES]> = (0..k)
            .map(|ci| {
                if counts[ci] == 0 {
                    centroids[ci]
                } else {
                    let mut c = [0.0; N_FEATURES];
                    for f in 0..N_FEATURES {
                        c[f] = sums[ci][f] / counts[ci] as f64;
                    }
                    c
                }
            })
            .collect();
        reseed_empty_clusters(points, &d2, &counts, &mut new_centroids);
        (new_centroids, assignment, inertia)
    }
}

/// Fixed chunk width for the parallel D² refresh in [`kmeanspp_init`].
/// Like [`STEP_CHUNK`], boundaries depend only on this constant so the
/// refreshed distances are bit-identical for any thread count.
const KPP_CHUNK: usize = 1024;

/// K-means++ seeding: first centroid uniform, the rest D²-weighted.
pub fn kmeanspp_init(
    points: &[[f64; N_FEATURES]],
    k: usize,
    rng: &mut Rng,
) -> Vec<[f64; N_FEATURES]> {
    assert!(!points.is_empty() && k >= 1);
    let mut centroids = Vec::with_capacity(k);
    centroids.push(points[rng.index(points.len())]);
    let mut d2: Vec<f64> = points
        .iter()
        .map(|p| sqdist(p, &centroids[0]))
        .collect();
    while centroids.len() < k {
        let total: f64 = d2.iter().sum();
        let next = if total <= 1e-18 {
            // all points coincide with existing centroids
            points[rng.index(points.len())]
        } else {
            let mut target = rng.f64() * total;
            let mut chosen = points.len() - 1;
            for (i, &d) in d2.iter().enumerate() {
                target -= d;
                if target <= 0.0 {
                    chosen = i;
                    break;
                }
            }
            points[chosen]
        };
        centroids.push(next);
        // Per-point distance refresh: element i depends only on its own
        // previous value, so it fans out over the pool.  The D²-weighted
        // centroid-selection scan above stays sequential — each draw
        // depends on the refreshed distances of the previous one.
        d2 = par::par_chunk_map(points, KPP_CHUNK, |start, window| {
            window
                .iter()
                .enumerate()
                .map(|(j, p)| d2[start + j].min(sqdist(p, &next)))
                .collect()
        });
    }
    centroids
}

/// Full K-means++ run: seeding + Lloyd until convergence (relative
/// inertia change < tol) or `max_iter`.
pub fn kmeans(
    points: &[[f64; N_FEATURES]],
    k: usize,
    rng: &mut Rng,
    backend: &dyn KmeansBackend,
) -> Clustering {
    let mut centroids = kmeanspp_init(points, k, rng);
    let mut last_inertia = f64::INFINITY;
    let mut assignment = vec![0; points.len()];
    let mut inertia = 0.0;
    for _ in 0..100 {
        let (c, a, i) = backend.step(points, &centroids);
        centroids = c;
        assignment = a;
        inertia = i;
        if (last_inertia - inertia).abs() <= 1e-9 * last_inertia.max(1e-12) {
            break;
        }
        last_inertia = inertia;
    }
    Clustering {
        centroids,
        assignment,
        inertia,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blobs(rng: &mut Rng, centers: &[[f64; N_FEATURES]], per: usize) -> Vec<[f64; N_FEATURES]> {
        let mut pts = Vec::new();
        for c in centers {
            for _ in 0..per {
                let mut p = *c;
                for f in p.iter_mut() {
                    *f += rng.normal() * 0.1;
                }
                pts.push(p);
            }
        }
        pts
    }

    fn well_separated() -> Vec<[f64; N_FEATURES]> {
        let mut rng = Rng::new(1);
        blobs(
            &mut rng,
            &[
                [0.0, 0.0, 0.0, 0.0],
                [10.0, 0.0, 0.0, 0.0],
                [0.0, 10.0, 0.0, 0.0],
            ],
            50,
        )
    }

    #[test]
    fn recovers_separated_blobs() {
        let pts = well_separated();
        let mut rng = Rng::new(2);
        let res = kmeans(&pts, 3, &mut rng, &NativeKmeans);
        // every blob of 50 consecutive points must be pure
        for b in 0..3 {
            let labels = &res.assignment[b * 50..(b + 1) * 50];
            assert!(labels.iter().all(|&l| l == labels[0]), "blob {b} split");
        }
        assert!(res.inertia < 150.0 * 0.1, "inertia={}", res.inertia);
    }

    #[test]
    fn inertia_nonincreasing_over_steps() {
        let pts = well_separated();
        let mut rng = Rng::new(5);
        let mut centroids = kmeanspp_init(&pts, 3, &mut rng);
        let mut prev = f64::INFINITY;
        for _ in 0..10 {
            let (c, _, inertia) = NativeKmeans.step(&pts, &centroids);
            assert!(inertia <= prev + 1e-9);
            prev = inertia;
            centroids = c;
        }
    }

    #[test]
    fn init_picks_distinct_centroids_when_possible() {
        let pts = well_separated();
        let mut rng = Rng::new(7);
        let cents = kmeanspp_init(&pts, 3, &mut rng);
        // D^2 seeding on separated blobs lands one centroid per blob
        // with overwhelming probability
        let mut hit = [false; 3];
        for c in &cents {
            if c[0] < 5.0 && c[1] < 5.0 {
                hit[0] = true;
            } else if c[0] >= 5.0 {
                hit[1] = true;
            } else {
                hit[2] = true;
            }
        }
        assert!(hit.iter().all(|&h| h), "{cents:?}");
    }

    #[test]
    fn degenerate_identical_points() {
        let pts = vec![[1.0, 2.0, 3.0, 4.0]; 20];
        let mut rng = Rng::new(3);
        let res = kmeans(&pts, 3, &mut rng, &NativeKmeans);
        assert!(res.inertia < 1e-12);
        assert!(res.assignment.iter().all(|&a| a < 3));
    }

    #[test]
    fn k_equals_one() {
        let pts = well_separated();
        let mut rng = Rng::new(4);
        let res = kmeans(&pts, 1, &mut rng, &NativeKmeans);
        assert!(res.assignment.iter().all(|&a| a == 0));
    }

    #[test]
    fn empty_cluster_reseeds_from_farthest_point() {
        // Nine points at the origin plus one outlier: the far centroid
        // attracts nothing and must be reseeded onto the outlier, not
        // left stranded at its stale position.
        let mut pts = vec![[0.0; N_FEATURES]; 10];
        pts[7] = [3.0; N_FEATURES];
        let centroids = vec![[0.0; N_FEATURES], [100.0; N_FEATURES]];
        let (c, a, _) = NativeKmeans.step(&pts, &centroids);
        assert!(a.iter().all(|&x| x == 0));
        assert_eq!(c[1], [3.0; N_FEATURES], "reseed onto the farthest point");
        assert_eq!(c[0], [0.3; N_FEATURES], "mean of the assigned points");
    }

    #[test]
    fn multiple_empty_clusters_take_successive_farthest_points() {
        let mut pts = vec![[0.0; N_FEATURES]; 8];
        pts[2] = [5.0; N_FEATURES];
        pts[5] = [4.0; N_FEATURES];
        let centroids = vec![
            [0.0; N_FEATURES],
            [100.0; N_FEATURES],
            [200.0; N_FEATURES],
        ];
        let (c, _, _) = NativeKmeans.step(&pts, &centroids);
        assert_eq!(c[1], [5.0; N_FEATURES]);
        assert_eq!(c[2], [4.0; N_FEATURES]);
    }

    #[test]
    fn reseed_recovers_dead_cluster_within_a_full_run() {
        // Small-n fixture that used to strand a dead cluster: two tight
        // groups plus one outlier, k = 3.  With reseeding, the outlier
        // ends up owning its own cluster and inertia drops accordingly.
        let mut pts = vec![[0.0; N_FEATURES]; 6];
        for p in pts.iter_mut().take(3) {
            p[0] = 1.0;
        }
        pts[5] = [50.0; N_FEATURES];
        let mut rng = Rng::new(11);
        let res = kmeans(&pts, 3, &mut rng, &NativeKmeans);
        let outlier_label = res.assignment[5];
        assert!(
            res.assignment[..5].iter().all(|&l| l != outlier_label),
            "outlier should own a cluster: {:?}",
            res.assignment
        );
        assert!(res.inertia < 2.0, "inertia={}", res.inertia);
    }
}
