//! Throughput-surface assembly (§4.1.2): turn a cluster's log entries
//! into per-(load-bucket, pp-slice) value grids over the (p, cc) knot
//! lattice, fit bicubic surfaces through a pluggable backend (native
//! math or the PJRT-compiled JAX/Pallas pipeline), and attach Gaussian
//! confidence regions.

use crate::logs::generator::PARAM_GRID;
use crate::offline::confidence::ConfidenceRegion;
use crate::offline::spline::BicubicSurface;
use crate::Params;

/// The shared knot lattice: the distinct p/cc values present in
/// real-world logs (tools use small powers of two — see
/// `logs::generator`).  Fixed so surface batches share knots, which is
/// what lets the AOT artifacts use one static shape.
pub fn knot_lattice() -> Vec<f64> {
    PARAM_GRID.iter().map(|&v| v as f64).collect()
}

/// A (p, cc) value grid with replication counts, one pp-slice of one
/// load bucket of one cluster.
#[derive(Debug, Clone)]
pub struct SurfaceGrid {
    pub xs: Vec<f64>,
    pub ys: Vec<f64>,
    pub values: Vec<Vec<f64>>,
    pub counts: Vec<Vec<usize>>,
    /// fraction of cells with at least one observation
    pub coverage: f64,
}

impl SurfaceGrid {
    /// Accumulate observations onto the lattice (cell mean).  Cells
    /// without data are filled by iterative neighbor averaging so the
    /// spline fit stays well-posed; `coverage` records how much was
    /// real data.
    pub fn from_observations(obs: &[(Params, f64)]) -> SurfaceGrid {
        let xs = knot_lattice();
        let ys = knot_lattice();
        let gp = xs.len();
        let gc = ys.len();
        let mut sum = vec![vec![0.0f64; gc]; gp];
        let mut counts = vec![vec![0usize; gc]; gp];
        let idx_of = |v: u32| xs.iter().position(|&k| k == v as f64);
        for (q, th) in obs {
            if let (Some(i), Some(j)) = (idx_of(q.p), idx_of(q.cc)) {
                sum[i][j] += th;
                counts[i][j] += 1;
            }
        }
        let mut values = vec![vec![f64::NAN; gc]; gp];
        let mut filled = 0usize;
        for i in 0..gp {
            for j in 0..gc {
                if counts[i][j] > 0 {
                    values[i][j] = sum[i][j] / counts[i][j] as f64;
                    filled += 1;
                }
            }
        }
        let coverage = filled as f64 / (gp * gc) as f64;

        // iterative fill: every NaN becomes the mean of its non-NaN
        // 4-neighbours until the grid is complete
        let mut guard = 0;
        while values.iter().flatten().any(|v| v.is_nan()) {
            let snapshot = values.clone();
            for i in 0..gp {
                for j in 0..gc {
                    if snapshot[i][j].is_nan() {
                        let mut acc = 0.0;
                        let mut n = 0usize;
                        let mut push = |v: f64| {
                            if !v.is_nan() {
                                acc += v;
                                n += 1;
                            }
                        };
                        if i > 0 {
                            push(snapshot[i - 1][j]);
                        }
                        if i + 1 < gp {
                            push(snapshot[i + 1][j]);
                        }
                        if j > 0 {
                            push(snapshot[i][j - 1]);
                        }
                        if j + 1 < gc {
                            push(snapshot[i][j + 1]);
                        }
                        if n > 0 {
                            values[i][j] = acc / n as f64;
                        }
                    }
                }
            }
            guard += 1;
            if guard > gp + gc {
                // fully empty grid: zero-fill
                for row in &mut values {
                    for v in row.iter_mut() {
                        if v.is_nan() {
                            *v = 0.0;
                        }
                    }
                }
            }
        }
        SurfaceGrid {
            xs,
            ys,
            values,
            counts,
            coverage,
        }
    }
}

/// Output of a surface fit, backend-independent.
#[derive(Debug, Clone)]
pub struct FittedSurface {
    pub surface: BicubicSurface,
    /// dense-refinement maximum (folded with the knot-grid max)
    pub max_th: f64,
    /// (p, cc) coordinates of the maximum
    pub max_at: (f64, f64),
    pub grid_mean: f64,
    pub grid_std: f64,
}

/// Backend for the batched fit + dense-refine + stats step.  The native
/// implementation lives here; `runtime::accel::PjrtSurfaceBackend` runs
/// the same computation through the AOT artifacts (parity-tested).
/// `Sync` is a supertrait so `&dyn SurfaceBackend` can be shared by the
/// pool workers that fan the pipeline's per-cluster fits out.
pub trait SurfaceBackend: Sync {
    /// All grids share (xs, ys).  `rf` is the dense refinement factor.
    fn fit_batch(
        &self,
        xs: &[f64],
        ys: &[f64],
        values: &[Vec<Vec<f64>>],
        rf: usize,
    ) -> Vec<FittedSurface>;

    fn name(&self) -> &'static str {
        "backend"
    }
}

/// Pure-Rust backend (offline::spline).
pub struct NativeSurfaceBackend;

impl SurfaceBackend for NativeSurfaceBackend {
    fn fit_batch(
        &self,
        xs: &[f64],
        ys: &[f64],
        values: &[Vec<Vec<f64>>],
        rf: usize,
    ) -> Vec<FittedSurface> {
        // Each grid's fit is independent; fan out over the pool (the
        // outputs are reassembled in input order).
        crate::util::par::par_map(values, |_, grid| {
            let surface = BicubicSurface::fit(xs, ys, grid);
            let dense = surface.dense_eval(rf);
            let mut max_v = f64::NEG_INFINITY;
            let mut max_ij = (0usize, 0usize);
            for (i, row) in dense.iter().enumerate() {
                for (j, &v) in row.iter().enumerate() {
                    if v > max_v {
                        max_v = v;
                        max_ij = (i, j);
                    }
                }
            }
            let mut max_at = surface.refined_to_coords(max_ij.0, max_ij.1, rf);
            // fold in the raw knot grid (left-closed refinement never
            // samples the far boundary)
            for (i, row) in grid.iter().enumerate() {
                for (j, &v) in row.iter().enumerate() {
                    if v > max_v {
                        max_v = v;
                        max_at = (xs[i], ys[j]);
                    }
                }
            }
            let flat: Vec<f64> = grid.iter().flatten().copied().collect();
            FittedSurface {
                surface,
                max_th: max_v,
                max_at,
                grid_mean: crate::util::stats::mean(&flat),
                grid_std: crate::util::stats::std_pop(&flat),
            }
        })
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

/// One fitted pp-slice surface with its paper §4.1 annotations.
#[derive(Debug, Clone)]
pub struct ThroughputSurface {
    pub pp: u32,
    pub load_bucket: usize,
    /// mean true intensity of the bucket's entries (the surface's
    /// "external load intensity information" tag)
    pub load_intensity: f64,
    pub fitted: FittedSurface,
    pub confidence: ConfidenceRegion,
    /// argmax as integer protocol parameters
    pub optimal_params: Params,
    pub optimal_th: f64,
    /// observations used (diagnostics / additive updates)
    pub n_obs: usize,
    pub coverage: f64,
}

impl ThroughputSurface {
    /// Predict throughput at integer parameters (pp is this slice's).
    pub fn predict(&self, params: Params) -> f64 {
        self.fitted.surface.eval(params.p as f64, params.cc as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn obs_from_fn<F: Fn(f64, f64) -> f64>(f: F, noise: f64, seed: u64) -> Vec<(Params, f64)> {
        let mut rng = Rng::new(seed);
        let mut obs = Vec::new();
        for &p in &PARAM_GRID {
            for &cc in &PARAM_GRID {
                for _ in 0..3 {
                    let th = f(p as f64, cc as f64) * (1.0 + noise * rng.normal());
                    obs.push((Params::new(cc, p, 4), th));
                }
            }
        }
        obs
    }

    #[test]
    fn grid_cell_means() {
        let obs = vec![
            (Params::new(1, 1, 4), 10.0),
            (Params::new(1, 1, 4), 20.0),
            (Params::new(2, 4, 4), 50.0),
        ];
        let g = SurfaceGrid::from_observations(&obs);
        assert_eq!(g.values[0][0], 15.0); // p=1 (idx 0), cc=1 (idx 0)
        // p=4 is index 2 in the lattice [1,2,4,...], cc=2 index 1
        assert_eq!(g.values[2][1], 50.0);
        assert_eq!(g.counts[0][0], 2);
        assert!(g.coverage > 0.0 && g.coverage < 0.1);
    }

    #[test]
    fn fill_completes_sparse_grids() {
        let obs = vec![(Params::new(1, 1, 4), 100.0)];
        let g = SurfaceGrid::from_observations(&obs);
        assert!(g.values.iter().flatten().all(|v| v.is_finite()));
        // the only observation should propagate everywhere
        assert!(g.values.iter().flatten().all(|&v| (v - 100.0).abs() < 1e-9));
    }

    #[test]
    fn empty_grid_zero_fills() {
        let g = SurfaceGrid::from_observations(&[]);
        assert!(g.values.iter().flatten().all(|&v| v == 0.0));
        assert_eq!(g.coverage, 0.0);
    }

    #[test]
    fn native_backend_finds_the_peak() {
        // concave bump peaking near p=8, cc=8
        let f = |p: f64, cc: f64| 1_000.0 - (p - 8.0).powi(2) * 6.0 - (cc - 8.0).powi(2) * 6.0;
        let obs = obs_from_fn(f, 0.0, 1);
        let grid = SurfaceGrid::from_observations(&obs);
        let fits =
            NativeSurfaceBackend.fit_batch(&grid.xs, &grid.ys, &[grid.values.clone()], 8);
        assert_eq!(fits.len(), 1);
        let fit = &fits[0];
        assert!((fit.max_th - 1_000.0).abs() < 30.0, "max={}", fit.max_th);
        assert!((fit.max_at.0 - 8.0).abs() < 1.5, "at p={}", fit.max_at.0);
        assert!((fit.max_at.1 - 8.0).abs() < 1.5, "at cc={}", fit.max_at.1);
    }

    #[test]
    fn boundary_max_is_found() {
        // monotone increasing: max sits at the far corner (32, 32),
        // which left-closed dense refinement alone would miss
        let f = |p: f64, cc: f64| p * 10.0 + cc * 5.0;
        let obs = obs_from_fn(f, 0.0, 2);
        let grid = SurfaceGrid::from_observations(&obs);
        let fits =
            NativeSurfaceBackend.fit_batch(&grid.xs, &grid.ys, &[grid.values.clone()], 8);
        let fit = &fits[0];
        assert!((fit.max_at.0 - 32.0).abs() < 1e-9);
        assert!((fit.max_at.1 - 32.0).abs() < 1e-9);
        assert!((fit.max_th - 480.0).abs() < 1e-6);
    }

    #[test]
    fn batch_fit_handles_many_surfaces() {
        let grids: Vec<Vec<Vec<f64>>> = (0..5)
            .map(|k| {
                let f = |p: f64, cc: f64| 100.0 * (k + 1) as f64 - (p - 4.0).powi(2) - cc;
                let obs = obs_from_fn(f, 0.0, k as u64);
                SurfaceGrid::from_observations(&obs).values
            })
            .collect();
        let xs = knot_lattice();
        let fits = NativeSurfaceBackend.fit_batch(&xs, &xs, &grids, 4);
        assert_eq!(fits.len(), 5);
        for w in fits.windows(2) {
            assert!(w[1].max_th > w[0].max_th);
        }
    }
}
