//! The five-phase offline pipeline (§4.1) assembled into an additive,
//! queryable [`KnowledgeBase`]:
//!
//! 1. cluster the log corpus ([`crate::offline::clustering`]);
//! 2. reconstruct external-load intensity per entry (rank of the
//!    residual against same-parameter peers — real logs do not carry a
//!    load tag) and bucket it;
//! 3. per (cluster × bucket × pp slice): assemble the (p, cc) grid and
//!    batch-fit bicubic surfaces through the [`SurfaceBackend`];
//! 4. Gaussian confidence region per surface (fit residuals, Eq 12–14);
//! 5. maxima + suitable sampling regions (Eq 17–19).
//!
//! "Additive": [`KnowledgeBase::update`] folds new log entries in by
//! re-fitting only the clusters they touch — the clustering itself and
//! every untouched cluster's surfaces are reused, matching §4's "we do
//! not need to ... perform analysis on the entire log from scratch".

use crate::logs::schema::LogEntry;
use crate::offline::clustering::{cluster_logs, LogClustering};
use crate::offline::confidence::ConfidenceRegion;
use crate::offline::kmeans::{KmeansBackend, NativeKmeans};
use crate::offline::regions::{suitable_regions, RegionConfig, SamplePoint};
use crate::offline::surface::{
    NativeSurfaceBackend, SurfaceBackend, SurfaceGrid, ThroughputSurface,
};
use crate::util::json::Value;
use crate::util::par;
use crate::Params;
use std::collections::BTreeMap;

/// Offline-phase configuration.
#[derive(Debug, Clone)]
pub struct OfflineConfig {
    /// number of external-load intensity buckets per cluster
    pub n_load_buckets: usize,
    /// maximum k for the CH-index sweep
    pub k_max: usize,
    /// dense-refinement factor for maxima search
    pub rf: usize,
    /// confidence-band width in σ
    pub z: f64,
    /// minimum observations for a (bucket, pp) slice to get a surface
    pub min_slice_obs: usize,
    pub regions: RegionConfig,
    pub seed: u64,
}

impl Default for OfflineConfig {
    fn default() -> Self {
        OfflineConfig {
            n_load_buckets: 4,
            k_max: 6,
            rf: 8,
            z: 2.0,
            min_slice_obs: 12,
            regions: RegionConfig::default(),
            seed: 0x0FF1,
        }
    }
}

/// All surfaces of one load bucket (one per pp slice), plus the
/// bucket-level optimum the online phase jumps to.
#[derive(Debug, Clone)]
pub struct LoadBucketSurfaces {
    pub bucket: usize,
    /// reconstructed intensity tag in [0, 1]
    pub load_intensity: f64,
    /// mean *true* intensity (generator ground truth) — used only by
    /// validation experiments, never by the optimizer itself
    pub true_intensity: f64,
    pub slices: Vec<ThroughputSurface>,
    pub optimal_params: Params,
    pub optimal_th: f64,
}

impl LoadBucketSurfaces {
    /// The slice whose pp is closest to `params.pp`.
    pub fn slice_for(&self, params: Params) -> &ThroughputSurface {
        self.slices
            .iter()
            .min_by_key(|s| (s.pp as i64 - params.pp as i64).abs())
            // pallas-lint: allow(panic-in-lib, buckets with zero slices are dropped by the retain() at build time, so every surviving bucket has a slice)
            .expect("bucket has at least one slice")
    }

    /// Predict throughput at integer parameters.
    pub fn predict(&self, params: Params) -> f64 {
        self.slice_for(params).predict(params)
    }

    /// Confidence check at the prediction point.
    pub fn contains(&self, params: Params, achieved: f64) -> bool {
        let s = self.slice_for(params);
        s.confidence.contains(s.predict(params), achieved)
    }
}

/// Queryable per-(cluster, file-size-class) knowledge: load-sorted
/// surfaces + sampling regions — exactly what Algorithm 1's `QueryDB`
/// returns (`F_s, R_s, I_s`).  Clusters are subdivided by file-size
/// class before surface fitting: throughput at the same (p, cc, pp) is
/// radically different for 1 MB and 1 GB files, and mixing them would
/// average the surfaces into uselessness (the paper likewise treats
/// small/medium/large transfers separately, §5.1).
#[derive(Debug, Clone)]
pub struct SurfaceSet {
    pub cluster: usize,
    pub class: crate::sim::dataset::FileSizeClass,
    /// sorted ascending by `load_intensity`
    pub buckets: Vec<LoadBucketSurfaces>,
    pub sampling: Vec<SamplePoint>,
}

impl SurfaceSet {
    /// Index of the median-load bucket (Algorithm 1 line 3).
    pub fn median_bucket(&self) -> usize {
        self.buckets.len() / 2
    }
}

/// The offline knowledge base.
pub struct KnowledgeBase {
    pub cfg: OfflineConfig,
    pub clustering: LogClustering,
    pub sets: Vec<SurfaceSet>,
    /// retained corpus (enables additive updates)
    entries: Vec<LogEntry>,
}

/// Reconstruct per-entry load intensity inside one cluster: entries are
/// ranked by their residual against the mean throughput of their exact
/// parameter group; a low residual means heavier external load.
fn estimate_loads(entries: &[&LogEntry]) -> Vec<f64> {
    let mut group_sum: BTreeMap<(u32, u32, u32), (f64, usize)> = BTreeMap::new();
    for e in entries {
        let k = (e.params.cc, e.params.p, e.params.pp);
        let g = group_sum.entry(k).or_insert((0.0, 0));
        g.0 += e.throughput_mbps;
        g.1 += 1;
    }
    let residual: Vec<f64> = entries
        .iter()
        .map(|e| {
            let k = (e.params.cc, e.params.p, e.params.pp);
            let (s, n) = group_sum[&k];
            let mean = s / n as f64;
            if mean > 0.0 {
                e.throughput_mbps / mean
            } else {
                1.0
            }
        })
        .collect();
    // rank -> intensity: the smallest residual is the heaviest load
    let mut order: Vec<usize> = (0..residual.len()).collect();
    order.sort_by(|&a, &b| residual[a].total_cmp(&residual[b]));
    let n = residual.len().max(2) as f64;
    let mut intensity = vec![0.0; residual.len()];
    for (rank, &idx) in order.iter().enumerate() {
        intensity[idx] = 1.0 - rank as f64 / (n - 1.0);
    }
    intensity
}

/// Build the surfaces of one (cluster, file-size-class) slice.
fn build_cluster_set(
    cluster: usize,
    class: crate::sim::dataset::FileSizeClass,
    entries: &[&LogEntry],
    cfg: &OfflineConfig,
    backend: &dyn SurfaceBackend,
) -> SurfaceSet {
    let loads = estimate_loads(entries);
    let nb = cfg.n_load_buckets;

    // (bucket, pp) -> observations
    let mut slices: BTreeMap<(usize, u32), Vec<(Params, f64)>> = BTreeMap::new();
    let mut bucket_loads: Vec<Vec<f64>> = vec![Vec::new(); nb];
    let mut bucket_true: Vec<Vec<f64>> = vec![Vec::new(); nb];
    for (e, &load) in entries.iter().zip(&loads) {
        let b = ((load * nb as f64) as usize).min(nb - 1);
        bucket_loads[b].push(load);
        bucket_true[b].push(e.true_load);
        slices
            .entry((b, e.params.pp))
            .or_default()
            .push((e.params, e.throughput_mbps));
    }

    // assemble grids slice by slice, batching the backend call
    let mut grid_meta: Vec<(usize, u32, SurfaceGrid, Vec<(Params, f64)>)> = Vec::new();
    for ((b, pp), obs) in slices {
        if obs.len() < cfg.min_slice_obs {
            continue;
        }
        let grid = SurfaceGrid::from_observations(&obs);
        grid_meta.push((b, pp, grid, obs));
    }

    let mut buckets: Vec<LoadBucketSurfaces> = (0..nb)
        .map(|b| LoadBucketSurfaces {
            bucket: b,
            load_intensity: crate::util::stats::mean(&bucket_loads[b]),
            true_intensity: crate::util::stats::mean(&bucket_true[b]),
            slices: Vec::new(),
            optimal_params: Params::DEFAULT,
            optimal_th: 0.0,
        })
        .collect();

    if !grid_meta.is_empty() {
        let xs = grid_meta[0].2.xs.clone();
        let ys = grid_meta[0].2.ys.clone();
        let values: Vec<Vec<Vec<f64>>> =
            grid_meta.iter().map(|(_, _, g, _)| g.values.clone()).collect();
        let fits = backend.fit_batch(&xs, &ys, &values, cfg.rf);

        for ((b, pp, grid, obs), fitted) in grid_meta.into_iter().zip(fits) {
            // Gaussian confidence from fit residuals (Eq 12-14)
            let residuals: Vec<f64> = obs
                .iter()
                .map(|(q, th)| th - fitted.surface.eval(q.p as f64, q.cc as f64))
                .collect();
            let confidence =
                ConfidenceRegion::from_residuals(&residuals, fitted.max_th, cfg.z);
            let mut optimal_params = Params::new(
                fitted.max_at.1.round().max(1.0) as u32,
                fitted.max_at.0.round().max(1.0) as u32,
                pp,
            );
            let mut optimal_th = fitted.max_th;
            // anti-overshoot guard: a spline ridge can extrapolate past
            // anything actually observed (oscillation near steep decay);
            // when the fitted max clears the best *observed* cell by
            // more than the confidence band, trust the data
            let mut best_obs: Option<(Params, f64)> = None;
            for (i, row) in grid.counts.iter().enumerate() {
                for (j, &n) in row.iter().enumerate() {
                    if n > 0 {
                        let v = grid.values[i][j];
                        if best_obs.map_or(true, |(_, b)| v > b) {
                            best_obs = Some((
                                Params::new(grid.ys[j] as u32, grid.xs[i] as u32, pp),
                                v,
                            ));
                        }
                    }
                }
            }
            if let Some((q, v)) = best_obs {
                if optimal_th > v + confidence.band() {
                    optimal_params = q;
                    optimal_th = v;
                }
            }
            let bucket_intensity = buckets[b].load_intensity;
            buckets[b].slices.push(ThroughputSurface {
                pp,
                load_bucket: b,
                load_intensity: bucket_intensity,
                fitted,
                confidence,
                optimal_params,
                optimal_th,
                n_obs: obs.len(),
                coverage: grid.coverage,
            });
        }
    }

    // bucket optima = best slice
    for b in &mut buckets {
        if let Some(best) = b
            .slices
            .iter()
            .max_by(|x, y| x.optimal_th.total_cmp(&y.optimal_th))
        {
            b.optimal_params = best.optimal_params;
            b.optimal_th = best.optimal_th;
        }
        b.slices.sort_by_key(|s| s.pp);
    }
    // drop empty buckets, sort by load
    buckets.retain(|b| !b.slices.is_empty());
    buckets.sort_by(|a, b| a.load_intensity.total_cmp(&b.load_intensity));

    let all_surfaces: Vec<ThroughputSurface> = buckets
        .iter()
        .flat_map(|b| b.slices.iter().cloned())
        .collect();
    let sampling = suitable_regions(&all_surfaces, &cfg.regions);

    SurfaceSet {
        cluster,
        class,
        buckets,
        sampling,
    }
}

impl KnowledgeBase {
    /// Full offline analysis over a log corpus.
    pub fn build(
        entries: Vec<LogEntry>,
        cfg: OfflineConfig,
        surface_backend: &dyn SurfaceBackend,
        kmeans_backend: &dyn KmeansBackend,
    ) -> KnowledgeBase {
        assert!(!entries.is_empty(), "offline analysis needs logs");
        let refs: Vec<&LogEntry> = entries.iter().collect();
        let clustering = cluster_logs(&refs, cfg.k_max, cfg.seed, kmeans_backend);
        // Every (cluster, file-size class) cell is an independent fit:
        // fan the cells out over the pool and keep the survivors in
        // cell order (identical to the sequential double loop).
        let work: Vec<(usize, crate::sim::dataset::FileSizeClass)> = (0..clustering.k)
            .flat_map(|c| {
                crate::sim::dataset::FileSizeClass::all()
                    .into_iter()
                    .map(move |class| (c, class))
            })
            .collect();
        let built = par::par_map(&work, |_, &(c, class)| {
            let members: Vec<&LogEntry> = entries
                .iter()
                .zip(&clustering.labels)
                .filter(|(e, &l)| {
                    l == c
                        && crate::sim::dataset::FileSizeClass::classify(e.avg_file_mb)
                            == class
                })
                .map(|(e, _)| e)
                .collect();
            if members.len() < cfg.min_slice_obs {
                return None;
            }
            let set = build_cluster_set(c, class, &members, &cfg, surface_backend);
            if set.buckets.is_empty() {
                None
            } else {
                Some(set)
            }
        });
        let sets: Vec<SurfaceSet> = built.into_iter().flatten().collect();
        KnowledgeBase {
            cfg,
            clustering,
            sets,
            entries,
        }
    }

    /// Convenience: build with the native backends.
    pub fn build_native(entries: Vec<LogEntry>, cfg: OfflineConfig) -> KnowledgeBase {
        KnowledgeBase::build(entries, cfg, &NativeSurfaceBackend, &NativeKmeans)
    }

    /// Algorithm-1 `QueryDB`: the surface set of the closest cluster.
    pub fn query(
        &self,
        rtt_s: f64,
        bandwidth_mbps: f64,
        avg_file_mb: f64,
        n_files: u64,
    ) -> Option<&SurfaceSet> {
        let f = self
            .clustering
            .scaler
            .transform_query(rtt_s, bandwidth_mbps, avg_file_mb, n_files);
        let cluster = self.clustering.assign_query(&f);
        let class = crate::sim::dataset::FileSizeClass::classify(avg_file_mb);
        self.sets
            .iter()
            .find(|s| s.cluster == cluster && s.class == class)
            // class determines the parameter regime more than cluster:
            // prefer a same-class set from another cluster over a
            // different-class set from the right cluster
            .or_else(|| self.sets.iter().find(|s| s.class == class))
            .or_else(|| self.sets.iter().find(|s| s.cluster == cluster))
            .or_else(|| {
                // nothing matched: fall back to any available set
                self.sets.first()
            })
    }

    /// Additive update: append new entries, re-fit only the clusters
    /// they land in.
    pub fn update(&mut self, new_entries: Vec<LogEntry>, surface_backend: &dyn SurfaceBackend) {
        if new_entries.is_empty() {
            return;
        }
        let mut touched: Vec<usize> = Vec::new();
        for e in &new_entries {
            let f = self.clustering.scaler.transform(e);
            let c = self.clustering.assign_query(&f);
            if !touched.contains(&c) {
                touched.push(c);
            }
            self.clustering.labels.push(c);
        }
        self.entries.extend(new_entries);

        // Touched (cluster, class) cells are refit in parallel, then
        // spliced back in serially (cell order) so set ordering stays
        // deterministic.
        let work: Vec<(usize, crate::sim::dataset::FileSizeClass)> = touched
            .iter()
            .flat_map(|&c| {
                crate::sim::dataset::FileSizeClass::all()
                    .into_iter()
                    .map(move |class| (c, class))
            })
            .collect();
        let entries = &self.entries;
        let clustering = &self.clustering;
        let cfg = &self.cfg;
        let rebuilt_cells = par::par_map(&work, |_, &(c, class)| {
            let members: Vec<&LogEntry> = entries
                .iter()
                .zip(&clustering.labels)
                .filter(|(e, &l)| {
                    l == c
                        && crate::sim::dataset::FileSizeClass::classify(e.avg_file_mb)
                            == class
                })
                .map(|(e, _)| e)
                .collect();
            if members.len() < cfg.min_slice_obs {
                return None;
            }
            let rebuilt = build_cluster_set(c, class, &members, cfg, surface_backend);
            if rebuilt.buckets.is_empty() {
                None
            } else {
                Some(rebuilt)
            }
        });
        for rebuilt in rebuilt_cells.into_iter().flatten() {
            if let Some(slot) = self
                .sets
                .iter_mut()
                .find(|s| s.cluster == rebuilt.cluster && s.class == rebuilt.class)
            {
                *slot = rebuilt;
            } else {
                self.sets.push(rebuilt);
            }
        }
    }

    pub fn n_entries(&self) -> usize {
        self.entries.len()
    }

    /// Total number of fitted surfaces across clusters.
    pub fn n_surfaces(&self) -> usize {
        self.sets
            .iter()
            .map(|s| s.buckets.iter().map(|b| b.slices.len()).sum::<usize>())
            .sum()
    }

    /// Order-sensitive FNV-1a digest over every numeric output of the
    /// pipeline: labels, centroids, CH score, per-slice surface
    /// coefficients, optima, confidence bands and sampling points.
    /// Equal digests mean bit-identical knowledge bases; the
    /// `prop_parallel` suite holds this invariant across
    /// `PALLAS_THREADS` settings.
    pub fn digest(&self) -> u64 {
        struct Fnv(u64);
        impl Fnv {
            fn u(&mut self, x: u64) {
                for byte in x.to_le_bytes() {
                    self.0 ^= byte as u64;
                    self.0 = self.0.wrapping_mul(0x0000_0100_0000_01B3);
                }
            }
            fn f(&mut self, v: f64) {
                self.u(v.to_bits());
            }
            fn params(&mut self, q: Params) {
                self.u(q.cc as u64);
                self.u(q.p as u64);
                self.u(q.pp as u64);
            }
        }
        let mut h = Fnv(0xCBF2_9CE4_8422_2325);
        h.u(self.clustering.k as u64);
        h.u(match self.clustering.algo {
            crate::offline::clustering::ClusterAlgo::KmeansPP => 0,
            crate::offline::clustering::ClusterAlgo::HacUpgma => 1,
        });
        h.f(self.clustering.ch_score);
        for &l in &self.clustering.labels {
            h.u(l as u64);
        }
        for c in &self.clustering.centroids {
            for &v in c {
                h.f(v);
            }
        }
        for set in &self.sets {
            h.u(set.cluster as u64);
            for byte in set.class.name().bytes() {
                h.u(byte as u64);
            }
            for sp in &set.sampling {
                h.params(sp.params);
                h.f(sp.separation);
                h.u(sp.from_maxima as u64);
            }
            for b in &set.buckets {
                h.u(b.bucket as u64);
                h.f(b.load_intensity);
                h.f(b.true_intensity);
                h.params(b.optimal_params);
                h.f(b.optimal_th);
                for s in &b.slices {
                    h.u(s.pp as u64);
                    h.u(s.n_obs as u64);
                    h.f(s.coverage);
                    h.params(s.optimal_params);
                    h.f(s.optimal_th);
                    h.f(s.confidence.sigma);
                    h.f(s.confidence.z);
                    h.f(s.fitted.max_th);
                    h.f(s.fitted.max_at.0);
                    h.f(s.fitted.max_at.1);
                    h.f(s.fitted.grid_mean);
                    h.f(s.fitted.grid_std);
                    for row in &s.fitted.surface.coeffs {
                        for patch in row {
                            for &c in patch {
                                h.f(c);
                            }
                        }
                    }
                }
            }
        }
        h.0
    }

    /// Compact JSON summary (CLI `offline --out`).
    pub fn summary_json(&self) -> Value {
        Value::obj(vec![
            ("entries", Value::Num(self.n_entries() as f64)),
            ("clusters", Value::Num(self.clustering.k as f64)),
            ("ch_score", Value::Num(self.clustering.ch_score)),
            ("surfaces", Value::Num(self.n_surfaces() as f64)),
            (
                "sets",
                Value::Arr(
                    self.sets
                        .iter()
                        .map(|s| {
                            Value::obj(vec![
                                ("cluster", Value::Num(s.cluster as f64)),
                                ("buckets", Value::Num(s.buckets.len() as f64)),
                                (
                                    "sampling_points",
                                    Value::Num(s.sampling.len() as f64),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logs::generator::{generate_history, GeneratorConfig};
    use crate::sim::profile::NetProfile;

    fn history(days: f64, seed: u64) -> Vec<LogEntry> {
        generate_history(
            &NetProfile::xsede(),
            &GeneratorConfig {
                days,
                transfers_per_hour: 12.0,
                seed,
            },
        )
    }

    fn kb(days: f64) -> KnowledgeBase {
        KnowledgeBase::build_native(history(days, 42), OfflineConfig::default())
    }

    #[test]
    fn builds_surfaces_from_history() {
        let kb = kb(14.0);
        assert!(kb.clustering.k >= 2);
        assert!(kb.n_surfaces() > 0, "no surfaces fitted");
        for set in &kb.sets {
            for b in &set.buckets {
                assert!(!b.slices.is_empty());
                assert!(b.optimal_th > 0.0);
                assert!((1..=32).contains(&b.optimal_params.p));
            }
            // buckets sorted by load
            for w in set.buckets.windows(2) {
                assert!(w[0].load_intensity <= w[1].load_intensity);
            }
        }
    }

    #[test]
    fn load_reconstruction_correlates_with_truth() {
        let kb = kb(14.0);
        // within each set, bucket order by estimated load must broadly
        // agree with the mean true intensity
        let mut checked = 0;
        for set in &kb.sets {
            if set.buckets.len() >= 2 {
                let first = set.buckets.first().unwrap();
                let last = set.buckets.last().unwrap();
                assert!(
                    last.true_intensity >= first.true_intensity - 0.08,
                    "bucket order disagrees with ground truth: {} vs {}",
                    first.true_intensity,
                    last.true_intensity
                );
                checked += 1;
            }
        }
        assert!(checked > 0);
    }

    #[test]
    fn heavier_buckets_predict_lower_peaks() {
        let kb = kb(14.0);
        let mut checked = 0;
        for set in &kb.sets {
            if set.buckets.len() >= 3 {
                let lightest = set.buckets.first().unwrap().optimal_th;
                let heaviest = set.buckets.last().unwrap().optimal_th;
                // allow some slack: sparse heavy buckets are noisy
                if heaviest < lightest {
                    checked += 1;
                }
            }
        }
        assert!(checked > 0, "no set shows load ordering in peak throughput");
    }

    #[test]
    fn query_returns_relevant_cluster() {
        let kb = kb(14.0);
        let p = NetProfile::xsede();
        let set = kb.query(p.rtt_s, p.bandwidth_mbps, 1_000.0, 50);
        assert!(set.is_some());
        let set = set.unwrap();
        assert!(!set.buckets.is_empty());
        assert!(!set.sampling.is_empty());
    }

    #[test]
    fn additive_update_only_touches_affected_clusters() {
        let mut kb = kb(10.0);
        let before_surfaces = kb.n_surfaces();
        let before_entries = kb.n_entries();
        let extra = history(3.0, 777);
        let n_extra = extra.len();
        kb.update(extra, &NativeSurfaceBackend);
        assert_eq!(kb.n_entries(), before_entries + n_extra);
        assert!(kb.n_surfaces() >= before_surfaces.saturating_sub(2));
        // labels stay consistent
        assert_eq!(kb.clustering.labels.len(), kb.n_entries());
    }

    #[test]
    fn surfaces_predict_training_data_reasonably() {
        let entries = history(14.0, 42);
        let kb = KnowledgeBase::build_native(entries.clone(), OfflineConfig::default());
        // median relative error of per-bucket predictions on training
        // points should be modest (surfaces average over load-bucket
        // noise, so individual entries deviate)
        let mut errs = Vec::new();
        for e in entries.iter().take(500) {
            if let Some(set) = kb.query(e.rtt_s, e.bandwidth_mbps, e.avg_file_mb, e.n_files) {
                // best-matching bucket for this entry's observed value
                let best = set
                    .buckets
                    .iter()
                    .map(|b| (b.predict(e.params) - e.throughput_mbps).abs())
                    .fold(f64::INFINITY, f64::min);
                errs.push(best / e.throughput_mbps.max(1.0));
            }
        }
        let med = crate::util::stats::median(&errs);
        assert!(med < 0.30, "median relative error {med}");
    }

    #[test]
    fn median_bucket_index() {
        let kb = kb(10.0);
        for set in &kb.sets {
            let m = set.median_bucket();
            assert!(m < set.buckets.len());
        }
    }
}
