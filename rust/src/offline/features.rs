//! Log-entry featurization for clustering.
//!
//! The paper clusters logs "based on different matrices" — transfers
//! that behave alike must land together.  We use the Eq-1 conditioning
//! variables that are *known before a transfer runs*: network (RTT,
//! bandwidth) and dataset (average file size, file count), log-scaled
//! (they span orders of magnitude) and z-normalized.

use crate::logs::schema::LogEntry;

/// Number of clustering features.
pub const N_FEATURES: usize = 4;

/// Raw (un-normalized) feature vector of one entry.
pub fn raw_features(e: &LogEntry) -> [f64; N_FEATURES] {
    [
        e.rtt_s.max(1e-6).ln(),
        e.bandwidth_mbps.max(1.0).ln(),
        e.avg_file_mb.max(1e-3).ln(),
        (e.n_files as f64).max(1.0).ln(),
    ]
}

/// Feature normalization (z-score) fitted on a log corpus and reused
/// for online queries — queries must be scaled exactly like the
/// training logs.
#[derive(Debug, Clone, PartialEq)]
pub struct FeatureScaler {
    pub mean: [f64; N_FEATURES],
    pub std: [f64; N_FEATURES],
}

impl FeatureScaler {
    pub fn fit(entries: &[&LogEntry]) -> FeatureScaler {
        let n = entries.len().max(1) as f64;
        let mut mean = [0.0; N_FEATURES];
        for e in entries {
            let f = raw_features(e);
            for k in 0..N_FEATURES {
                mean[k] += f[k];
            }
        }
        for m in &mut mean {
            *m /= n;
        }
        let mut var = [0.0; N_FEATURES];
        for e in entries {
            let f = raw_features(e);
            for k in 0..N_FEATURES {
                var[k] += (f[k] - mean[k]).powi(2);
            }
        }
        let mut std = [0.0; N_FEATURES];
        for k in 0..N_FEATURES {
            std[k] = (var[k] / n).sqrt().max(1e-9);
        }
        FeatureScaler { mean, std }
    }

    pub fn apply(&self, raw: [f64; N_FEATURES]) -> [f64; N_FEATURES] {
        let mut out = [0.0; N_FEATURES];
        for k in 0..N_FEATURES {
            out[k] = (raw[k] - self.mean[k]) / self.std[k];
        }
        out
    }

    pub fn transform(&self, e: &LogEntry) -> [f64; N_FEATURES] {
        self.apply(raw_features(e))
    }

    /// Featurize an online query (no log entry yet).
    pub fn transform_query(
        &self,
        rtt_s: f64,
        bandwidth_mbps: f64,
        avg_file_mb: f64,
        n_files: u64,
    ) -> [f64; N_FEATURES] {
        self.apply([
            rtt_s.max(1e-6).ln(),
            bandwidth_mbps.max(1.0).ln(),
            avg_file_mb.max(1e-3).ln(),
            (n_files as f64).max(1.0).ln(),
        ])
    }
}

/// Squared Euclidean distance between feature vectors.
pub fn sqdist(a: &[f64; N_FEATURES], b: &[f64; N_FEATURES]) -> f64 {
    let mut s = 0.0;
    for k in 0..N_FEATURES {
        let d = a[k] - b[k];
        s += d * d;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::Params;

    fn entry(rtt: f64, bw: f64, favg: f64, nf: u64) -> LogEntry {
        LogEntry {
            timestamp_s: 0.0,
            network: "x".into(),
            rtt_s: rtt,
            bandwidth_mbps: bw,
            avg_file_mb: favg,
            n_files: nf,
            params: Params::DEFAULT,
            throughput_mbps: 1.0,
            true_load: 0.0,
        }
    }

    #[test]
    fn normalization_zero_mean_unit_std() {
        let es: Vec<LogEntry> = (1..=20)
            .map(|i| entry(0.01 * i as f64, 1000.0 * i as f64, i as f64, i * 10))
            .collect();
        let refs: Vec<&LogEntry> = es.iter().collect();
        let sc = FeatureScaler::fit(&refs);
        let feats: Vec<[f64; 4]> = refs.iter().map(|e| sc.transform(e)).collect();
        for k in 0..N_FEATURES {
            let m: f64 = feats.iter().map(|f| f[k]).sum::<f64>() / feats.len() as f64;
            let v: f64 =
                feats.iter().map(|f| (f[k] - m).powi(2)).sum::<f64>() / feats.len() as f64;
            assert!(m.abs() < 1e-9, "feature {k} mean {m}");
            assert!((v - 1.0).abs() < 1e-6, "feature {k} var {v}");
        }
    }

    #[test]
    fn query_matches_entry_transform() {
        let es: Vec<LogEntry> = (1..=5)
            .map(|i| entry(0.04, 1e4, 2.0f64.powi(i), 100))
            .collect();
        let refs: Vec<&LogEntry> = es.iter().collect();
        let sc = FeatureScaler::fit(&refs);
        let e = &es[2];
        let a = sc.transform(e);
        let b = sc.transform_query(e.rtt_s, e.bandwidth_mbps, e.avg_file_mb, e.n_files);
        assert_eq!(a, b);
    }

    #[test]
    fn constant_feature_does_not_blow_up() {
        let es: Vec<LogEntry> = (0..6).map(|_| entry(0.04, 1e4, 8.0, 100)).collect();
        let refs: Vec<&LogEntry> = es.iter().collect();
        let sc = FeatureScaler::fit(&refs);
        let f = sc.transform(&es[0]);
        assert!(f.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn distance_separates_classes() {
        let small = entry(0.04, 1e4, 1.0, 10_000);
        let large = entry(0.04, 1e4, 2_000.0, 20);
        let es = [small.clone(), large.clone()];
        let refs: Vec<&LogEntry> = es.iter().collect();
        let sc = FeatureScaler::fit(&refs);
        let d = sqdist(&sc.transform(&small), &sc.transform(&large));
        assert!(d > 1.0, "classes should be far apart: {d}");
    }
}
