//! Hierarchical Agglomerative Clustering with UPGMA linkage — the
//! paper's second clustering option (§4.1.1, Eq 3).
//!
//! UPGMA merges the pair of clusters with minimum average inter-point
//! distance; implemented with a Lance–Williams update on the proximity
//! matrix.  The proximity matrix is built in parallel (one row per
//! pool unit, see `util::par`), and a per-row nearest-neighbour cache
//! (`row_min[i]` = closest active `j > i`) turns each merge's pair
//! search into an O(n) scan over cached minima instead of an O(n²)
//! matrix rescan — only rows whose cached neighbour was touched by the
//! merge are recomputed.  The pipeline still subsamples large corpora
//! before calling this, as noted in DESIGN.md.

use crate::offline::features::{sqdist, N_FEATURES};
use crate::util::par;

/// Cut the UPGMA dendrogram at `k` clusters; returns per-point labels
/// in 0..k (labels are compacted).
pub fn upgma(points: &[[f64; N_FEATURES]], k: usize) -> Vec<usize> {
    let n = points.len();
    assert!(k >= 1);
    if n == 0 {
        return vec![];
    }
    let k = k.min(n);

    // active cluster list: (members, size)
    let mut members: Vec<Vec<usize>> = (0..n).map(|i| vec![i]).collect();
    let mut active: Vec<bool> = vec![true; n];
    // proximity matrix of average inter-cluster distances (Euclidean),
    // built row-parallel; (i,j) and (j,i) compute the identical value.
    let idx: Vec<usize> = (0..n).collect();
    let mut dist: Vec<Vec<f64>> = par::par_map(&idx, |_, &i| {
        (0..n)
            .map(|j| {
                if j == i {
                    0.0
                } else {
                    sqdist(&points[i], &points[j]).sqrt()
                }
            })
            .collect()
    });

    // row_min[i]: (argmin j, distance) over active j > i, scanning j
    // ascending with a strict `<` so ties keep the lowest j — exactly
    // the pair the full lexicographic rescan would select.
    let recompute_row = |dist: &[Vec<f64>], active: &[bool], i: usize| -> Option<(usize, f64)> {
        let mut best: Option<(usize, f64)> = None;
        for j in i + 1..n {
            if !active[j] {
                continue;
            }
            let d = dist[i][j];
            let better = match best {
                None => true,
                Some((_, bd)) => d < bd,
            };
            if better {
                best = Some((j, d));
            }
        }
        best
    };
    let mut row_min: Vec<Option<(usize, f64)>> =
        par::par_map(&idx, |_, &i| recompute_row(&dist, &active, i));

    let mut n_active = n;
    while n_active > k {
        // closest active pair: O(n) scan over cached row minima; the
        // strict `<` over ascending i keeps the lowest (i, j) on ties.
        let (mut bi, mut bj, mut bd) = (usize::MAX, usize::MAX, f64::INFINITY);
        for i in 0..n {
            if !active[i] {
                continue;
            }
            if let Some((j, d)) = row_min[i] {
                if d < bd {
                    bd = d;
                    bi = i;
                    bj = j;
                }
            }
        }
        // merge bj into bi (bi < bj by construction); UPGMA (average
        // linkage) Lance–Williams:
        // d(i∪j, l) = (|i| d(i,l) + |j| d(j,l)) / (|i| + |j|)
        let (si, sj) = (members[bi].len() as f64, members[bj].len() as f64);
        for l in 0..n {
            if !active[l] || l == bi || l == bj {
                continue;
            }
            let d = (si * dist[bi][l] + sj * dist[bj][l]) / (si + sj);
            dist[bi][l] = d;
            dist[l][bi] = d;
        }
        let moved = std::mem::take(&mut members[bj]);
        members[bi].extend(moved);
        active[bj] = false;
        n_active -= 1;

        // Repair the nearest-neighbour cache.  Row bi changed wholesale;
        // rows l < bj are stale only if their cached neighbour was bi or
        // bj (full O(n) rescan) or if the merged cluster moved closer
        // than their cached minimum (O(1) update).  Rows l > bj never
        // reference bi or bj (they only look rightward) and are intact.
        row_min[bi] = recompute_row(&dist, &active, bi);
        for l in 0..bj {
            if !active[l] || l == bi {
                continue;
            }
            if let Some((j0, d0)) = row_min[l] {
                if j0 == bj || j0 == bi {
                    row_min[l] = recompute_row(&dist, &active, l);
                } else if l < bi {
                    let nd = dist[l][bi];
                    if nd < d0 || (nd == d0 && bi < j0) {
                        row_min[l] = Some((bi, nd));
                    }
                }
            }
        }
    }

    let mut labels = vec![0usize; n];
    let mut next = 0usize;
    for i in 0..n {
        if active[i] {
            for &m in &members[i] {
                labels[m] = next;
            }
            next += 1;
        }
    }
    labels
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn blob(rng: &mut Rng, center: [f64; N_FEATURES], n: usize) -> Vec<[f64; N_FEATURES]> {
        (0..n)
            .map(|_| {
                let mut p = center;
                for f in p.iter_mut() {
                    *f += rng.normal() * 0.05;
                }
                p
            })
            .collect()
    }

    #[test]
    fn separates_two_blobs() {
        let mut rng = Rng::new(1);
        let mut pts = blob(&mut rng, [0.0; N_FEATURES], 20);
        pts.extend(blob(&mut rng, [5.0; N_FEATURES], 20));
        let labels = upgma(&pts, 2);
        let first = labels[0];
        assert!(labels[..20].iter().all(|&l| l == first));
        assert!(labels[20..].iter().all(|&l| l == labels[20]));
        assert_ne!(first, labels[20]);
    }

    #[test]
    fn k_one_merges_everything() {
        let mut rng = Rng::new(2);
        let pts = blob(&mut rng, [0.0; N_FEATURES], 15);
        let labels = upgma(&pts, 1);
        assert!(labels.iter().all(|&l| l == 0));
    }

    #[test]
    fn k_equal_n_keeps_singletons() {
        let mut rng = Rng::new(3);
        let pts = blob(&mut rng, [0.0; N_FEATURES], 6);
        let labels = upgma(&pts, 6);
        let mut seen = labels.clone();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 6);
    }

    #[test]
    fn labels_are_compact() {
        let mut rng = Rng::new(4);
        let mut pts = blob(&mut rng, [0.0; N_FEATURES], 10);
        pts.extend(blob(&mut rng, [8.0; N_FEATURES], 10));
        pts.extend(blob(&mut rng, [16.0; N_FEATURES], 10));
        let labels = upgma(&pts, 3);
        let max = *labels.iter().max().unwrap();
        assert_eq!(max, 2, "labels must be 0..k: {labels:?}");
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert!(upgma(&[], 3).is_empty());
        assert_eq!(upgma(&[[1.0; N_FEATURES]], 3), vec![0]);
    }

    /// The pre-cache algorithm: full O(n²) matrix rescan per merge.
    /// Kept as the oracle for the row-min cache.
    fn upgma_reference(points: &[[f64; N_FEATURES]], k: usize) -> Vec<usize> {
        let n = points.len();
        if n == 0 {
            return vec![];
        }
        let k = k.min(n).max(1);
        let mut members: Vec<Vec<usize>> = (0..n).map(|i| vec![i]).collect();
        let mut active: Vec<bool> = vec![true; n];
        let mut dist = vec![vec![0.0f64; n]; n];
        for i in 0..n {
            for j in i + 1..n {
                let d = sqdist(&points[i], &points[j]).sqrt();
                dist[i][j] = d;
                dist[j][i] = d;
            }
        }
        let mut n_active = n;
        while n_active > k {
            let (mut bi, mut bj, mut bd) = (usize::MAX, usize::MAX, f64::INFINITY);
            for i in 0..n {
                if !active[i] {
                    continue;
                }
                for j in i + 1..n {
                    if active[j] && dist[i][j] < bd {
                        bd = dist[i][j];
                        bi = i;
                        bj = j;
                    }
                }
            }
            let (si, sj) = (members[bi].len() as f64, members[bj].len() as f64);
            for l in 0..n {
                if !active[l] || l == bi || l == bj {
                    continue;
                }
                let d = (si * dist[bi][l] + sj * dist[bj][l]) / (si + sj);
                dist[bi][l] = d;
                dist[l][bi] = d;
            }
            let moved = std::mem::take(&mut members[bj]);
            members[bi].extend(moved);
            active[bj] = false;
            n_active -= 1;
        }
        let mut labels = vec![0usize; n];
        let mut next = 0usize;
        for i in 0..n {
            if active[i] {
                for &m in &members[i] {
                    labels[m] = next;
                }
                next += 1;
            }
        }
        labels
    }

    #[test]
    fn row_min_cache_matches_full_rescan_oracle() {
        for seed in [10u64, 11, 12] {
            let mut rng = Rng::new(seed);
            let mut pts = blob(&mut rng, [0.0; N_FEATURES], 13);
            pts.extend(blob(&mut rng, [2.0; N_FEATURES], 9));
            pts.extend(blob(&mut rng, [5.0; N_FEATURES], 11));
            for k in [1, 2, 3, 5, 8] {
                assert_eq!(
                    upgma(&pts, k),
                    upgma_reference(&pts, k),
                    "seed={seed} k={k}"
                );
            }
        }
    }

    #[test]
    fn chains_merge_by_average_not_single_link() {
        // two tight pairs + a chain point between them: average linkage
        // assigns the chain point to the *closer pair on average*
        let pts = vec![
            [0.0, 0.0, 0.0, 0.0],
            [0.1, 0.0, 0.0, 0.0],
            [10.0, 0.0, 0.0, 0.0],
            [10.1, 0.0, 0.0, 0.0],
            [4.0, 0.0, 0.0, 0.0], // closer to the left pair
        ];
        let labels = upgma(&pts, 2);
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[2], labels[3]);
        assert_eq!(labels[4], labels[0]);
    }
}
