//! Hierarchical Agglomerative Clustering with UPGMA linkage — the
//! paper's second clustering option (§4.1.1, Eq 3).
//!
//! UPGMA merges the pair of clusters with minimum average inter-point
//! distance; implemented with a Lance–Williams update on the proximity
//! matrix (O(n³) worst case — the pipeline subsamples large corpora
//! before calling this, as noted in DESIGN.md).

use crate::offline::features::{sqdist, N_FEATURES};

/// Cut the UPGMA dendrogram at `k` clusters; returns per-point labels
/// in 0..k (labels are compacted).
pub fn upgma(points: &[[f64; N_FEATURES]], k: usize) -> Vec<usize> {
    let n = points.len();
    assert!(k >= 1);
    if n == 0 {
        return vec![];
    }
    let k = k.min(n);

    // active cluster list: (members, size)
    let mut members: Vec<Vec<usize>> = (0..n).map(|i| vec![i]).collect();
    let mut active: Vec<bool> = vec![true; n];
    // proximity matrix of average inter-cluster distances (Euclidean)
    let mut dist = vec![vec![0.0f64; n]; n];
    for i in 0..n {
        for j in i + 1..n {
            let d = sqdist(&points[i], &points[j]).sqrt();
            dist[i][j] = d;
            dist[j][i] = d;
        }
    }

    let mut n_active = n;
    while n_active > k {
        // find the closest active pair
        let (mut bi, mut bj, mut bd) = (usize::MAX, usize::MAX, f64::INFINITY);
        for i in 0..n {
            if !active[i] {
                continue;
            }
            for j in i + 1..n {
                if !active[j] {
                    continue;
                }
                if dist[i][j] < bd {
                    bd = dist[i][j];
                    bi = i;
                    bj = j;
                }
            }
        }
        // merge bj into bi; UPGMA (average linkage) Lance–Williams:
        // d(i∪j, l) = (|i| d(i,l) + |j| d(j,l)) / (|i| + |j|)
        let (si, sj) = (members[bi].len() as f64, members[bj].len() as f64);
        for l in 0..n {
            if !active[l] || l == bi || l == bj {
                continue;
            }
            let d = (si * dist[bi][l] + sj * dist[bj][l]) / (si + sj);
            dist[bi][l] = d;
            dist[l][bi] = d;
        }
        let moved = std::mem::take(&mut members[bj]);
        members[bi].extend(moved);
        active[bj] = false;
        n_active -= 1;
    }

    let mut labels = vec![0usize; n];
    let mut next = 0usize;
    for i in 0..n {
        if active[i] {
            for &m in &members[i] {
                labels[m] = next;
            }
            next += 1;
        }
    }
    labels
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn blob(rng: &mut Rng, center: [f64; N_FEATURES], n: usize) -> Vec<[f64; N_FEATURES]> {
        (0..n)
            .map(|_| {
                let mut p = center;
                for f in p.iter_mut() {
                    *f += rng.normal() * 0.05;
                }
                p
            })
            .collect()
    }

    #[test]
    fn separates_two_blobs() {
        let mut rng = Rng::new(1);
        let mut pts = blob(&mut rng, [0.0; N_FEATURES], 20);
        pts.extend(blob(&mut rng, [5.0; N_FEATURES], 20));
        let labels = upgma(&pts, 2);
        let first = labels[0];
        assert!(labels[..20].iter().all(|&l| l == first));
        assert!(labels[20..].iter().all(|&l| l == labels[20]));
        assert_ne!(first, labels[20]);
    }

    #[test]
    fn k_one_merges_everything() {
        let mut rng = Rng::new(2);
        let pts = blob(&mut rng, [0.0; N_FEATURES], 15);
        let labels = upgma(&pts, 1);
        assert!(labels.iter().all(|&l| l == 0));
    }

    #[test]
    fn k_equal_n_keeps_singletons() {
        let mut rng = Rng::new(3);
        let pts = blob(&mut rng, [0.0; N_FEATURES], 6);
        let labels = upgma(&pts, 6);
        let mut seen = labels.clone();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), 6);
    }

    #[test]
    fn labels_are_compact() {
        let mut rng = Rng::new(4);
        let mut pts = blob(&mut rng, [0.0; N_FEATURES], 10);
        pts.extend(blob(&mut rng, [8.0; N_FEATURES], 10));
        pts.extend(blob(&mut rng, [16.0; N_FEATURES], 10));
        let labels = upgma(&pts, 3);
        let max = *labels.iter().max().unwrap();
        assert_eq!(max, 2, "labels must be 0..k: {labels:?}");
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert!(upgma(&[], 3).is_empty());
        assert_eq!(upgma(&[[1.0; N_FEATURES]], 3), vec![0]);
    }

    #[test]
    fn chains_merge_by_average_not_single_link() {
        // two tight pairs + a chain point between them: average linkage
        // assigns the chain point to the *closer pair on average*
        let pts = vec![
            [0.0, 0.0, 0.0, 0.0],
            [0.1, 0.0, 0.0, 0.0],
            [10.0, 0.0, 0.0, 0.0],
            [10.1, 0.0, 0.0, 0.0],
            [4.0, 0.0, 0.0, 0.0], // closer to the left pair
        ];
        let labels = upgma(&pts, 2);
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[2], labels[3]);
        assert_eq!(labels[4], labels[0]);
    }
}
