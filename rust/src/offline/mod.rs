//! The offline phase (§4.1): knowledge discovery over historical logs.
//!
//! Five phases, mirroring the paper:
//! 1. [`clustering`]/[`kmeans`]/[`hac`]/[`chindex`] — cluster logs in
//!    hierarchy (K-means++ and HAC/UPGMA, cluster count by the
//!    Calinski–Harabasz index);
//! 2. [`surface`]/[`spline`] — piecewise bicubic throughput surfaces
//!    per (cluster × load bucket × pp slice), with [`regression`] as
//!    the Fig-4(b) accuracy baselines;
//! 3. [`confidence`] — Gaussian confidence regions (Eq 12–14);
//! 4. [`maxima`] — surface maxima via the second-partial-derivative
//!    (Hessian) test;
//! 5. [`regions`] — suitable sampling regions `R_s = R_m ∪ R_c`
//!    (Eq 17–19).
//!
//! [`pipeline`] chains them into the additive [`pipeline::KnowledgeBase`]
//! the online phase queries.  The numerically heavy fit+refine step goes
//! through the [`surface::SurfaceBackend`] trait: [`spline`] provides
//! the native implementation, `runtime::accel` the PJRT-accelerated one
//! running the AOT-compiled JAX/Pallas artifacts.  Heavy stages fan
//! out over the deterministic pool in `util::par`; [`cache`] memoizes
//! converged tuning decisions across transfers.

pub mod cache;
pub mod chindex;
pub mod clustering;
pub mod confidence;
pub mod features;
pub mod hac;
pub mod kmeans;
pub mod maxima;
pub mod pipeline;
pub mod regions;
pub mod regression;
pub mod spline;
pub mod surface;

pub use cache::{CacheStats, CachedTuning, Fingerprint, TuningCache};
pub use pipeline::{KnowledgeBase, OfflineConfig, SurfaceSet};
pub use spline::{BicubicSurface, Spline1D};
pub use surface::ThroughputSurface;
