//! Calinski–Harabasz index (Eq 4) for choosing the number of clusters:
//! `CH(m) = [B/(m−1)] / [W/(n−m)]` with B the between-cluster and W the
//! within-cluster sum of squares.  Larger is better.
//!
//! (The paper's Eq 4–6 swap the Φ labels — a typesetting slip; we use
//! the standard definition the cited index actually has.)

use crate::offline::features::{sqdist, N_FEATURES};

/// CH score of a labelled clustering.  Returns 0 for degenerate cases
/// (m < 2 or m >= n) so callers can maximize without special-casing.
pub fn ch_index(points: &[[f64; N_FEATURES]], labels: &[usize]) -> f64 {
    let n = points.len();
    assert_eq!(n, labels.len());
    let m = labels.iter().copied().max().map_or(0, |x| x + 1);
    if m < 2 || m >= n {
        return 0.0;
    }

    // overall mean
    let mut overall = [0.0; N_FEATURES];
    for p in points {
        for f in 0..N_FEATURES {
            overall[f] += p[f];
        }
    }
    for v in &mut overall {
        *v /= n as f64;
    }

    // per-cluster means
    let mut sums = vec![[0.0; N_FEATURES]; m];
    let mut counts = vec![0usize; m];
    for (p, &l) in points.iter().zip(labels) {
        counts[l] += 1;
        for f in 0..N_FEATURES {
            sums[l][f] += p[f];
        }
    }
    let means: Vec<[f64; N_FEATURES]> = (0..m)
        .map(|c| {
            let mut mu = [0.0; N_FEATURES];
            if counts[c] > 0 {
                for f in 0..N_FEATURES {
                    mu[f] = sums[c][f] / counts[c] as f64;
                }
            }
            mu
        })
        .collect();

    let mut between = 0.0;
    for c in 0..m {
        between += counts[c] as f64 * sqdist(&means[c], &overall);
    }
    let mut within = 0.0;
    for (p, &l) in points.iter().zip(labels) {
        within += sqdist(p, &means[l]);
    }
    if within <= 1e-300 {
        return f64::MAX / 2.0; // perfect separation
    }
    (between / (m - 1) as f64) / (within / (n - m) as f64)
}

/// Pick the k in `2..=k_max` maximizing CH under a clustering function.
pub fn best_k<F: FnMut(usize) -> Vec<usize>>(
    points: &[[f64; N_FEATURES]],
    k_max: usize,
    mut cluster_fn: F,
) -> (usize, Vec<usize>, f64) {
    let mut best = (2usize, Vec::new(), f64::NEG_INFINITY);
    for k in 2..=k_max.max(2) {
        let labels = cluster_fn(k);
        let score = ch_index(points, &labels);
        if score > best.2 {
            best = (k, labels, score);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::offline::kmeans::{kmeans, NativeKmeans};
    use crate::util::rng::Rng;

    fn three_blobs() -> Vec<[f64; N_FEATURES]> {
        let mut rng = Rng::new(9);
        let centers = [
            [0.0, 0.0, 0.0, 0.0],
            [8.0, 0.0, 0.0, 0.0],
            [0.0, 8.0, 0.0, 0.0],
        ];
        let mut pts = Vec::new();
        for c in &centers {
            for _ in 0..40 {
                let mut p = *c;
                for f in p.iter_mut() {
                    *f += rng.normal() * 0.3;
                }
                pts.push(p);
            }
        }
        pts
    }

    #[test]
    fn true_k_scores_highest() {
        let pts = three_blobs();
        let mut rng = Rng::new(1);
        let (k, _, score) = best_k(&pts, 6, |k| {
            kmeans(&pts, k, &mut rng, &NativeKmeans).assignment
        });
        assert_eq!(k, 3, "CH should pick the true blob count");
        assert!(score > 100.0);
    }

    #[test]
    fn degenerate_cases_are_zero() {
        let pts = three_blobs();
        let all_zero = vec![0usize; pts.len()];
        assert_eq!(ch_index(&pts, &all_zero), 0.0);
        let singletons: Vec<usize> = (0..pts.len()).collect();
        assert_eq!(ch_index(&pts, &singletons), 0.0);
    }

    #[test]
    fn good_split_beats_bad_split() {
        let pts = three_blobs();
        // true labels
        let good: Vec<usize> = (0..120).map(|i| i / 40).collect();
        // random-ish bad labels
        let bad: Vec<usize> = (0..120).map(|i| i % 3).collect();
        assert!(ch_index(&pts, &good) > 10.0 * ch_index(&pts, &bad));
    }
}
