//! Clustering orchestration (§4.1.1): featurize a log corpus, choose k
//! by the CH index, and compare K-means++ against HAC/UPGMA, keeping
//! whichever scores higher (the paper evaluates both).

use crate::logs::schema::LogEntry;
use crate::offline::chindex::ch_index;
use crate::offline::features::{sqdist, FeatureScaler, N_FEATURES};
use crate::offline::hac::upgma;
use crate::offline::kmeans::{kmeans, KmeansBackend};
use crate::util::par;
use crate::util::rng::Rng;

/// Which algorithm won the CH-index comparison.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterAlgo {
    KmeansPP,
    HacUpgma,
}

/// Final clustering over a log corpus.
#[derive(Debug, Clone)]
pub struct LogClustering {
    pub scaler: FeatureScaler,
    pub centroids: Vec<[f64; N_FEATURES]>,
    /// per-entry cluster label, parallel to the input corpus
    pub labels: Vec<usize>,
    pub k: usize,
    pub algo: ClusterAlgo,
    pub ch_score: f64,
}

impl LogClustering {
    /// Nearest-centroid lookup for an online query.
    pub fn assign_query(&self, features: &[f64; N_FEATURES]) -> usize {
        self.centroids
            .iter()
            .enumerate()
            .min_by(|(_, a), (_, b)| {
                sqdist(features, a).total_cmp(&sqdist(features, b))
            })
            .map(|(i, _)| i)
            .unwrap_or(0)
    }
}

/// HAC is O(n³); subsample above this size and assign the rest to the
/// nearest resulting centroid.
const HAC_MAX_POINTS: usize = 300;

fn centroids_of(
    points: &[[f64; N_FEATURES]],
    labels: &[usize],
    k: usize,
) -> Vec<[f64; N_FEATURES]> {
    let mut sums = vec![[0.0; N_FEATURES]; k];
    let mut counts = vec![0usize; k];
    for (p, &l) in points.iter().zip(labels) {
        counts[l] += 1;
        for f in 0..N_FEATURES {
            sums[l][f] += p[f];
        }
    }
    (0..k)
        .map(|c| {
            let mut mu = [0.0; N_FEATURES];
            for f in 0..N_FEATURES {
                mu[f] = if counts[c] > 0 {
                    sums[c][f] / counts[c] as f64
                } else {
                    0.0
                };
            }
            mu
        })
        .collect()
}

fn assign_to_centroids(
    points: &[[f64; N_FEATURES]],
    centroids: &[[f64; N_FEATURES]],
) -> Vec<usize> {
    // Per-point labels are independent; fixed 512-point chunks fan out
    // over the pool with thread-invariant output order.
    par::par_chunk_map(points, 512, |_, window| {
        window
            .iter()
            .map(|p| {
                centroids
                    .iter()
                    .enumerate()
                    .min_by(|(_, a), (_, b)| {
                        sqdist(p, a).total_cmp(&sqdist(p, b))
                    })
                    .map(|(i, _)| i)
                    .unwrap_or(0)
            })
            .collect()
    })
}

/// Cluster a log corpus: fit the scaler, sweep k in 2..=k_max with both
/// algorithms, keep the CH-best labelling.
pub fn cluster_logs(
    entries: &[&LogEntry],
    k_max: usize,
    seed: u64,
    backend: &dyn KmeansBackend,
) -> LogClustering {
    assert!(!entries.is_empty(), "cannot cluster an empty corpus");
    let scaler = FeatureScaler::fit(entries);
    let points: Vec<[f64; N_FEATURES]> =
        entries.iter().map(|e| scaler.transform(e)).collect();
    let mut rng = Rng::new(seed ^ 0x636c7573);

    // Each k of the sweep is an independent unit: draw its RNG seed
    // up front (serially, so the seed sequence is fixed) and fan the
    // units out over the pool.  Both algorithm candidates for one k
    // are produced by the same unit.
    let units: Vec<(usize, u64)> = (2..=k_max.max(2))
        .map(|k| (k, rng.next_u64()))
        .collect();
    let candidates: Vec<(LogClustering, LogClustering)> =
        par::par_map(&units, |_, &(k, unit_seed)| {
            let mut rng = Rng::new(unit_seed);
            // K-means++
            let km = kmeans(&points, k, &mut rng, backend);
            let km_score = ch_index(&points, &km.assignment);
            let cand_km = LogClustering {
                scaler: scaler.clone(),
                centroids: km.centroids,
                labels: km.assignment,
                k,
                algo: ClusterAlgo::KmeansPP,
                ch_score: km_score,
            };

            // HAC/UPGMA (subsampled when large)
            let hac_labels = if points.len() <= HAC_MAX_POINTS {
                upgma(&points, k)
            } else {
                let mut idx: Vec<usize> = (0..points.len()).collect();
                rng.shuffle(&mut idx);
                let sample: Vec<[f64; N_FEATURES]> = idx[..HAC_MAX_POINTS]
                    .iter()
                    .map(|&i| points[i])
                    .collect();
                let sub_labels = upgma(&sample, k);
                let cents = centroids_of(&sample, &sub_labels, k);
                assign_to_centroids(&points, &cents)
            };
            let hac_score = ch_index(&points, &hac_labels);
            let cand_hac = LogClustering {
                scaler: scaler.clone(),
                centroids: centroids_of(&points, &hac_labels, k),
                labels: hac_labels,
                k,
                algo: ClusterAlgo::HacUpgma,
                ch_score: hac_score,
            };
            (cand_km, cand_hac)
        });

    // CH-best selection stays serial and in k order (K-means++ before
    // HAC within each k, strict `>`), so the winner is the one the
    // sequential sweep would have kept.
    let mut best: Option<LogClustering> = None;
    for (cand_km, cand_hac) in candidates {
        if best.as_ref().map_or(true, |b| cand_km.ch_score > b.ch_score) {
            best = Some(cand_km);
        }
        if best.as_ref().map_or(true, |b| cand_hac.ch_score > b.ch_score) {
            best = Some(cand_hac);
        }
    }
    // The sweep range is non-empty (`2..=k_max.max(2)`), so `best` is
    // always set; the fallback keeps the library panic-free regardless.
    best.unwrap_or_else(|| LogClustering {
        scaler,
        centroids: vec![[0.0; N_FEATURES]],
        labels: vec![0; points.len()],
        k: 1,
        algo: ClusterAlgo::KmeansPP,
        ch_score: 0.0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logs::generator::{generate_history, GeneratorConfig};
    use crate::offline::kmeans::NativeKmeans;
    use crate::sim::profile::NetProfile;

    fn corpus() -> Vec<LogEntry> {
        let cfg = GeneratorConfig {
            days: 5.0,
            transfers_per_hour: 6.0,
            seed: 77,
        };
        let mut logs = generate_history(&NetProfile::xsede(), &cfg);
        logs.extend(generate_history(&NetProfile::didclab(), &cfg));
        logs
    }

    #[test]
    fn clusters_separate_networks() {
        let logs = corpus();
        let refs: Vec<&LogEntry> = logs.iter().collect();
        let c = cluster_logs(&refs, 6, 1, &NativeKmeans);
        // entries from different networks should essentially never share
        // a cluster (rtt differs by 200x, bw by 10x)
        let mut cross = 0usize;
        let mut total = 0usize;
        for (i, a) in logs.iter().enumerate() {
            for (j, b) in logs.iter().enumerate().skip(i + 1).take(50) {
                if a.network != b.network {
                    total += 1;
                    if c.labels[i] == c.labels[j] {
                        cross += 1;
                    }
                }
                let _ = j;
            }
        }
        assert!(
            (cross as f64) < 0.05 * total as f64,
            "{cross}/{total} cross-network pairs share clusters"
        );
    }

    #[test]
    fn query_assignment_is_consistent_with_labels() {
        let logs = corpus();
        let refs: Vec<&LogEntry> = logs.iter().collect();
        let c = cluster_logs(&refs, 6, 2, &NativeKmeans);
        let mut agree = 0usize;
        for (i, e) in logs.iter().enumerate().take(200) {
            let q = c.scaler.transform(e);
            if c.assign_query(&q) == c.labels[i] {
                agree += 1;
            }
        }
        // centroid assignment should agree with training labels for the
        // overwhelming majority (boundary points may flip)
        assert!(agree > 180, "only {agree}/200 agree");
    }

    #[test]
    fn ch_score_positive_and_k_in_range() {
        let logs = corpus();
        let refs: Vec<&LogEntry> = logs.iter().collect();
        let c = cluster_logs(&refs, 6, 3, &NativeKmeans);
        assert!(c.ch_score > 0.0);
        assert!((2..=6).contains(&c.k));
        assert_eq!(c.labels.len(), logs.len());
        assert!(c.labels.iter().all(|&l| l < c.k));
    }
}
