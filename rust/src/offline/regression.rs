//! Polynomial-regression surface baselines (§4.1.2 / Fig 4b): the paper
//! compares quadratic and cubic least-squares regression in (p, cc, pp)
//! against the piecewise cubic spline and finds the spline wins —
//! lower-order models underfit, global high-order models overfit.

use crate::util::linalg::{least_squares, Mat};
use crate::Params;

/// Degree of the polynomial surface model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Degree {
    Quadratic,
    Cubic,
}

/// Monomial design row for (p, cc, pp) up to `degree` total degree.
fn design_row(degree: Degree, p: f64, cc: f64, pp: f64) -> Vec<f64> {
    let max_deg = match degree {
        Degree::Quadratic => 2u32,
        Degree::Cubic => 3u32,
    };
    let mut row = Vec::new();
    for a in 0..=max_deg {
        for b in 0..=max_deg - a {
            for c in 0..=max_deg - a - b {
                row.push(p.powi(a as i32) * cc.powi(b as i32) * pp.powi(c as i32));
            }
        }
    }
    row
}

/// A fitted polynomial throughput model th ≈ poly(p, cc, pp).
#[derive(Debug, Clone)]
pub struct PolySurface {
    pub degree: Degree,
    pub coeffs: Vec<f64>,
    /// input standardization (keeps the normal equations conditioned)
    scale: [f64; 3],
}

impl PolySurface {
    /// Least-squares fit from (params, throughput) observations.
    /// Returns None with < coefficients observations or a singular fit.
    pub fn fit(degree: Degree, obs: &[(Params, f64)]) -> Option<PolySurface> {
        if obs.is_empty() {
            return None;
        }
        let scale = [
            obs.iter().map(|(q, _)| q.p as f64).fold(1.0, f64::max),
            obs.iter().map(|(q, _)| q.cc as f64).fold(1.0, f64::max),
            obs.iter().map(|(q, _)| q.pp as f64).fold(1.0, f64::max),
        ];
        let rows: Vec<Vec<f64>> = obs
            .iter()
            .map(|(q, _)| {
                design_row(
                    degree,
                    q.p as f64 / scale[0],
                    q.cc as f64 / scale[1],
                    q.pp as f64 / scale[2],
                )
            })
            .collect();
        let ncoef = rows[0].len();
        if obs.len() < ncoef {
            return None;
        }
        let a = Mat::from_rows(&rows);
        let b: Vec<f64> = obs.iter().map(|(_, th)| *th).collect();
        let coeffs = least_squares(&a, &b)?;
        Some(PolySurface {
            degree,
            coeffs,
            scale,
        })
    }

    pub fn predict(&self, params: Params) -> f64 {
        let row = design_row(
            self.degree,
            params.p as f64 / self.scale[0],
            params.cc as f64 / self.scale[1],
            params.pp as f64 / self.scale[2],
        );
        row.iter().zip(&self.coeffs).map(|(x, c)| x * c).sum()
    }

    /// Argmax over the bounded integer grid (the regression analogue of
    /// the spline maxima search; HARP's online step uses this).
    pub fn argmax_on_grid(&self, cap: u32) -> (Params, f64) {
        let vals: Vec<u32> = [1u32, 2, 3, 4, 6, 8, 12, 16, 24, 32]
            .into_iter()
            .filter(|&v| v <= cap)
            .collect();
        // One pool unit per cc plane; the serial in-order reduction
        // over per-plane partial bests replicates the sequential
        // strict-`>` scan exactly (first maximum wins on ties).
        let partials = crate::util::par::par_map(&vals, |_, &cc| {
            let mut best = (Params::DEFAULT, f64::NEG_INFINITY);
            for &p in &vals {
                for &pp in &vals {
                    let q = Params::new(cc, p, pp);
                    let v = self.predict(q);
                    if v > best.1 {
                        best = (q, v);
                    }
                }
            }
            best
        });
        let mut best = (Params::DEFAULT, f64::NEG_INFINITY);
        for part in partials {
            if part.1 > best.1 {
                best = part;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_truth(q: Params) -> f64 {
        let (p, cc, pp) = (q.p as f64, q.cc as f64, q.pp as f64);
        100.0 + 20.0 * p - 1.5 * p * p + 10.0 * cc - 0.8 * cc * cc + 2.0 * pp - 0.1 * pp * pp
            + 0.3 * p * cc
    }

    fn grid_obs<F: Fn(Params) -> f64>(f: F) -> Vec<(Params, f64)> {
        let mut obs = Vec::new();
        for &cc in &[1u32, 2, 4, 8, 16, 32] {
            for &p in &[1u32, 2, 4, 8, 16] {
                for &pp in &[1u32, 4, 16] {
                    let q = Params::new(cc, p, pp);
                    obs.push((q, f(q)));
                }
            }
        }
        obs
    }

    #[test]
    fn quadratic_recovers_quadratic_truth() {
        let obs = grid_obs(quad_truth);
        let m = PolySurface::fit(Degree::Quadratic, &obs).unwrap();
        for (q, th) in &obs {
            let pred = m.predict(*q);
            assert!(
                (pred - th).abs() < 1e-5 * th.abs().max(1.0),
                "{q}: {pred} vs {th}"
            );
        }
    }

    #[test]
    fn cubic_fits_cubic_term_quadratic_cannot() {
        let cubic_truth = |q: Params| quad_truth(q) + 0.05 * (q.p as f64).powi(3);
        let obs = grid_obs(cubic_truth);
        let mq = PolySurface::fit(Degree::Quadratic, &obs).unwrap();
        let mc = PolySurface::fit(Degree::Cubic, &obs).unwrap();
        let err = |m: &PolySurface| -> f64 {
            obs.iter()
                .map(|(q, th)| (m.predict(*q) - th).powi(2))
                .sum()
        };
        assert!(err(&mc) < err(&mq) * 0.1, "cubic should fit far better");
    }

    #[test]
    fn too_few_observations_is_none() {
        let obs = vec![(Params::new(1, 1, 1), 10.0); 3];
        assert!(PolySurface::fit(Degree::Quadratic, &obs).is_none());
    }

    #[test]
    fn argmax_lands_near_true_peak() {
        // peak of quad_truth: p ≈ 20/3, cc ≈ 6.4 (within grid), pp ≈ 10
        let obs = grid_obs(quad_truth);
        let m = PolySurface::fit(Degree::Quadratic, &obs).unwrap();
        let (best, _) = m.argmax_on_grid(32);
        assert!((4..=8).contains(&best.p), "{best}");
        assert!((4..=8).contains(&best.cc), "{best}");
        assert!((8..=16).contains(&best.pp), "{best}");
    }

    #[test]
    fn design_row_sizes() {
        assert_eq!(design_row(Degree::Quadratic, 1.0, 1.0, 1.0).len(), 10);
        assert_eq!(design_row(Degree::Cubic, 1.0, 1.0, 1.0).len(), 20);
    }
}
