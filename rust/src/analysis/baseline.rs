//! Baseline ratchet for `pallas-lint`.
//!
//! The baseline file (`rust/lint-baseline.txt`) records pre-existing
//! violations as `rule path count` lines.  CI compares a fresh scan
//! against it and fails in **both** directions:
//!
//! * a (rule, path) pair whose live count exceeds its allowance is a
//!   **new violation** — fix or suppress it;
//! * a pair whose live count dropped below its allowance is a **stale
//!   entry** — shrink or delete the baseline line, so the debt only
//!   ever ratchets down.

use std::collections::BTreeMap;

use crate::analysis::Violation;
use crate::bail;
use crate::util::err::{Context, Result};

/// Allowed violation counts keyed by `(rule, path)`.
#[derive(Debug, Default, Clone)]
pub struct Baseline {
    pub allowed: BTreeMap<(String, String), usize>,
}

/// Parse the `rule path count` baseline format.  Blank lines and `#`
/// comments are skipped; duplicate keys are rejected.
pub fn parse(text: &str) -> Result<Baseline> {
    let mut allowed: BTreeMap<(String, String), usize> = BTreeMap::new();
    for (idx, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (Some(rule), Some(path), Some(count)) =
            (parts.next(), parts.next(), parts.next())
        else {
            bail!("baseline line {}: expected `rule path count`", idx + 1);
        };
        if parts.next().is_some() {
            bail!("baseline line {}: trailing fields", idx + 1);
        }
        let count: usize = count
            .parse()
            .ok()
            .with_context(|| format!("baseline line {}: bad count `{count}`", idx + 1))?;
        if count == 0 {
            bail!("baseline line {}: zero-count entry is stale by definition", idx + 1);
        }
        let key = (rule.to_string(), path.to_string());
        if allowed.insert(key, count).is_some() {
            bail!("baseline line {}: duplicate entry for {rule} {path}", idx + 1);
        }
    }
    Ok(Baseline { allowed })
}

/// Live violation counts keyed by `(rule, path)`.
pub fn counts(violations: &[Violation]) -> BTreeMap<(String, String), usize> {
    let mut out: BTreeMap<(String, String), usize> = BTreeMap::new();
    for v in violations {
        *out.entry((v.rule.to_string(), v.path.clone())).or_insert(0) += 1;
    }
    out
}

/// A (rule, path) pair whose live count disagrees with the baseline.
#[derive(Debug, Clone)]
pub struct Delta {
    pub rule: String,
    pub path: String,
    pub allowed: usize,
    pub actual: usize,
}

/// Result of comparing a scan against the baseline.
#[derive(Debug, Default)]
pub struct Comparison {
    /// Pairs over their allowance, with the file's individual
    /// violations attached for reporting.
    pub over: Vec<(Delta, Vec<Violation>)>,
    /// Baseline entries whose debt was (partly) paid off.
    pub stale: Vec<Delta>,
}

impl Comparison {
    pub fn clean(&self) -> bool {
        self.over.is_empty() && self.stale.is_empty()
    }
}

/// Compare live violations against the baseline allowances.
pub fn compare(base: &Baseline, violations: &[Violation]) -> Comparison {
    let live = counts(violations);
    let mut cmp = Comparison::default();
    for (key, &actual) in &live {
        let allowed = base.allowed.get(key).copied().unwrap_or(0);
        if actual > allowed {
            let detail: Vec<Violation> = violations
                .iter()
                .filter(|v| v.rule == key.0 && v.path == key.1)
                .cloned()
                .collect();
            cmp.over.push((
                Delta {
                    rule: key.0.clone(),
                    path: key.1.clone(),
                    allowed,
                    actual,
                },
                detail,
            ));
        }
    }
    for (key, &allowed) in &base.allowed {
        let actual = live.get(key).copied().unwrap_or(0);
        if actual < allowed {
            cmp.stale.push(Delta {
                rule: key.0.clone(),
                path: key.1.clone(),
                allowed,
                actual,
            });
        }
    }
    cmp
}

/// Render violations as a fresh baseline file, sorted by (path, rule).
pub fn render(violations: &[Violation]) -> String {
    let live = counts(violations);
    let mut lines: Vec<String> = vec![
        "# pallas-lint baseline: pre-existing violations, ratcheted down only.".to_string(),
        "# Format: rule-id path count.  CI fails on counts above AND below".to_string(),
        "# these allowances (stale entries must be removed when debt is paid).".to_string(),
    ];
    let mut entries: Vec<(&(String, String), &usize)> = live.iter().collect();
    entries.sort_by(|a, b| (&a.0 .1, &a.0 .0).cmp(&(&b.0 .1, &b.0 .0)));
    for ((rule, path), count) in entries {
        lines.push(format!("{rule} {path} {count}"));
    }
    lines.push(String::new());
    lines.join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(rule: &'static str, path: &str, line: usize) -> Violation {
        Violation {
            rule,
            path: path.to_string(),
            line,
            snippet: String::new(),
        }
    }

    #[test]
    fn parse_round_trips_render() {
        let vs = vec![
            v("panic-in-lib", "src/a.rs", 3),
            v("panic-in-lib", "src/a.rs", 9),
            v("nondet-iteration", "src/b.rs", 1),
        ];
        let text = render(&vs);
        let base = parse(&text).expect("rendered baseline parses");
        assert_eq!(
            base.allowed
                .get(&("panic-in-lib".to_string(), "src/a.rs".to_string())),
            Some(&2)
        );
        assert!(compare(&base, &vs).clean());
    }

    #[test]
    fn overage_and_stale_are_flagged() {
        let base = parse("panic-in-lib src/a.rs 1\nnondet-iteration src/b.rs 2\n")
            .expect("parses");
        // a.rs grew to 2 (over), b.rs dropped to 0 (stale)
        let vs = vec![v("panic-in-lib", "src/a.rs", 3), v("panic-in-lib", "src/a.rs", 4)];
        let cmp = compare(&base, &vs);
        assert_eq!(cmp.over.len(), 1);
        assert_eq!(cmp.over[0].0.actual, 2);
        assert_eq!(cmp.over[0].0.allowed, 1);
        assert_eq!(cmp.over[0].1.len(), 2);
        assert_eq!(cmp.stale.len(), 1);
        assert_eq!(cmp.stale[0].path, "src/b.rs");
    }

    #[test]
    fn bad_lines_are_rejected() {
        assert!(parse("just-two fields\n").is_err());
        assert!(parse("a b c d\n").is_err());
        assert!(parse("a b notanumber\n").is_err());
        assert!(parse("a b 0\n").is_err());
        assert!(parse("a b 1\na b 2\n").is_err());
        assert!(parse("# comment\n\na b 3\n").is_ok());
    }
}
