//! Token-level Rust lexer for `pallas-lint`.
//!
//! Deliberately not a full parser: the lint rules only need a stream of
//! identifiers and punctuation with comments, string/char literals and
//! test-gated items out of the way.  Three jobs:
//!
//! 1. [`lex`] — strip line/nested-block comments, regular / raw / byte
//!    string literals and char literals (while distinguishing
//!    lifetimes), and emit [`Tok`]s with line numbers;
//! 2. [`lex`] also collects `// pallas-lint: allow(rule, reason)`
//!    [`Suppression`]s from line comments;
//! 3. [`strip_test_gated`] — drop any item behind a `#[cfg(...)]`
//!    attribute whose predicate mentions `test` (covers `cfg(test)`,
//!    `cfg(all(test, feature = "x"))`, ...), so test-only code is
//!    exempt from library rules.

/// One lexical token: an identifier, a number, `::`, or a single
/// punctuation character.  String and comment contents are never
/// emitted.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Tok {
    pub text: String,
    /// 1-based source line.
    pub line: usize,
}

/// A parsed `pallas-lint: allow(rule, reason)` comment.  An empty
/// `rule` marks a comment that mentioned pallas-lint but did not parse;
/// an empty `reason` marks a missing (mandatory) justification.  Both
/// are reported as `bad-suppression` violations by the scanner.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Suppression {
    /// Line the comment sits on; it applies to violations on this line
    /// and the next.
    pub line: usize,
    pub rule: String,
    pub reason: String,
}

/// Output of [`lex`].
#[derive(Debug, Clone)]
pub struct Lexed {
    pub toks: Vec<Tok>,
    pub suppressions: Vec<Suppression>,
}

/// Parse one line comment's text for a suppression directive.  The
/// directive must open the comment (`// pallas-lint: ...`); mentions of
/// the syntax mid-sentence or in doc comments (`/// ...`) are ignored.
fn parse_suppression(comment: &str, line: usize) -> Option<Suppression> {
    let trimmed = comment.trim_start();
    let rest = trimmed.strip_prefix("pallas-lint")?;
    let malformed = Suppression {
        line,
        rule: String::new(),
        reason: String::new(),
    };
    let rest = rest.trim_start();
    let Some(rest) = rest.strip_prefix(':') else {
        return Some(malformed);
    };
    let rest = rest.trim_start();
    let Some(rest) = rest.strip_prefix("allow") else {
        return Some(malformed);
    };
    let rest = rest.trim_start();
    let Some(rest) = rest.strip_prefix('(') else {
        return Some(malformed);
    };
    let Some(close) = rest.rfind(')') else {
        return Some(malformed);
    };
    let inner = &rest[..close];
    let (rule, reason) = match inner.split_once(',') {
        Some((r, why)) => (r.trim().to_string(), why.trim().to_string()),
        None => (inner.trim().to_string(), String::new()),
    };
    Some(Suppression { line, rule, reason })
}

/// Does a raw (or raw-byte) string literal start at `i`?  Returns the
/// index just past the opening quote plus the `#` count.
fn raw_string_open(b: &[u8], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if b.get(j) == Some(&b'b') {
        j += 1;
    }
    if b.get(j) != Some(&b'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while b.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    if b.get(j) == Some(&b'"') {
        Some((j + 1, hashes))
    } else {
        None
    }
}

/// Skip past a raw string body opened with `hashes` hash marks.
fn skip_raw_string(b: &[u8], mut j: usize, hashes: usize, line: &mut usize) -> usize {
    while j < b.len() {
        if b[j] == b'\n' {
            *line += 1;
            j += 1;
            continue;
        }
        if b[j] == b'"' {
            let mut k = j + 1;
            let mut seen = 0usize;
            while seen < hashes && b.get(k) == Some(&b'#') {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                return k;
            }
        }
        j += 1;
    }
    j
}

/// Skip a regular (escaped) string body; `j` points past the opening
/// quote.
fn skip_string(b: &[u8], mut j: usize, line: &mut usize) -> usize {
    while j < b.len() {
        match b[j] {
            b'\\' => j += 2,
            b'"' => return j + 1,
            b'\n' => {
                *line += 1;
                j += 1;
            }
            _ => j += 1,
        }
    }
    j
}

/// Width in bytes of the UTF-8 scalar starting at `c`.
fn utf8_width(c: u8) -> usize {
    if c < 0x80 {
        1
    } else if c < 0xE0 {
        2
    } else if c < 0xF0 {
        3
    } else {
        4
    }
}

/// Lex Rust source into tokens + suppression directives.
pub fn lex(src: &str) -> Lexed {
    let b = src.as_bytes();
    let mut toks: Vec<Tok> = Vec::new();
    let mut suppressions: Vec<Suppression> = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < b.len() {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        // line comment (incl. doc comments) — suppression carrier
        if c == b'/' && b.get(i + 1) == Some(&b'/') {
            let start = i + 2;
            let mut j = start;
            while j < b.len() && b[j] != b'\n' {
                j += 1;
            }
            if let Ok(text) = std::str::from_utf8(&b[start..j]) {
                if let Some(s) = parse_suppression(text, line) {
                    suppressions.push(s);
                }
            }
            i = j;
            continue;
        }
        // nested block comment
        if c == b'/' && b.get(i + 1) == Some(&b'*') {
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < b.len() && depth > 0 {
                if b[j] == b'\n' {
                    line += 1;
                    j += 1;
                } else if b[j] == b'/' && b.get(j + 1) == Some(&b'*') {
                    depth += 1;
                    j += 2;
                } else if b[j] == b'*' && b.get(j + 1) == Some(&b'/') {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        // string literal
        if c == b'"' {
            i = skip_string(b, i + 1, &mut line);
            continue;
        }
        // raw / raw-byte string literal (r"...", r#"..."#, br"...")
        if (c == b'r' || c == b'b') && raw_string_open(b, i).is_some() {
            if let Some((open, hashes)) = raw_string_open(b, i) {
                i = skip_raw_string(b, open, hashes, &mut line);
            }
            continue;
        }
        // char literal vs lifetime
        if c == b'\'' {
            match b.get(i + 1) {
                Some(&b'\\') => {
                    // escaped char literal: skip the escape head, then
                    // scan to the closing quote (covers \u{...})
                    let mut j = i + 3;
                    while j < b.len() && b[j] != b'\'' {
                        j += 1;
                    }
                    i = j + 1;
                }
                Some(&n) if n != b'\'' => {
                    let w = utf8_width(n);
                    if b.get(i + 1 + w) == Some(&b'\'') {
                        // plain char literal like 'a'
                        i += 2 + w;
                    } else {
                        // lifetime: drop the quote, lex the name as an
                        // ordinary identifier
                        i += 1;
                    }
                }
                _ => i += 1,
            }
            continue;
        }
        // identifier / keyword
        if c == b'_' || c.is_ascii_alphabetic() {
            let start = i;
            while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
                i += 1;
            }
            toks.push(Tok {
                text: src[start..i].to_string(),
                line,
            });
            continue;
        }
        // number (loose: enough to keep digits out of the punct stream
        // without eating `..` ranges)
        if c.is_ascii_digit() {
            let start = i;
            i += 1;
            while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
                i += 1;
            }
            if b.get(i) == Some(&b'.')
                && b.get(i + 1).map(|d| d.is_ascii_digit()).unwrap_or(false)
            {
                i += 1;
                while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
                    i += 1;
                }
            }
            toks.push(Tok {
                text: src[start..i].to_string(),
                line,
            });
            continue;
        }
        // path separator is the one multi-char operator the rules need
        if c == b':' && b.get(i + 1) == Some(&b':') {
            toks.push(Tok {
                text: "::".to_string(),
                line,
            });
            i += 2;
            continue;
        }
        if c < 0x80 {
            toks.push(Tok {
                text: (c as char).to_string(),
                line,
            });
            i += 1;
        } else {
            // non-ASCII outside strings/comments: skip the scalar
            i += utf8_width(c);
        }
    }
    Lexed {
        toks,
        suppressions,
    }
}

/// Is this attribute token list a test-gating `cfg`?  Any `cfg(...)`
/// whose predicate mentions `test` (and is not negated) gates its item
/// out of the library build the rules care about.
fn is_test_cfg(attr: &[Tok]) -> bool {
    if attr.first().map(|t| t.text.as_str()) != Some("cfg") {
        return false;
    }
    let has = |s: &str| attr.iter().any(|t| t.text == s);
    has("test") && !has("not")
}

/// Skip the item following a stripped attribute: further attributes,
/// then either a `;`-terminated item or a braced body.
fn skip_item(toks: &[Tok], mut i: usize) -> usize {
    loop {
        if toks.get(i).map(|t| t.text.as_str()) == Some("#") {
            let mut j = i + 1;
            if toks.get(j).map(|t| t.text.as_str()) == Some("!") {
                j += 1;
            }
            if toks.get(j).map(|t| t.text.as_str()) == Some("[") {
                let mut depth = 1usize;
                let mut k = j + 1;
                while k < toks.len() && depth > 0 {
                    match toks[k].text.as_str() {
                        "[" => depth += 1,
                        "]" => depth -= 1,
                        _ => {}
                    }
                    k += 1;
                }
                i = k;
                continue;
            }
        }
        break;
    }
    let mut depth = 0i64; // ( and [ nesting before the body
    while i < toks.len() {
        match toks[i].text.as_str() {
            "(" | "[" => depth += 1,
            ")" | "]" => depth -= 1,
            ";" if depth <= 0 => return i + 1,
            "{" if depth <= 0 => {
                let mut braces = 1i64;
                i += 1;
                while i < toks.len() && braces > 0 {
                    match toks[i].text.as_str() {
                        "{" => braces += 1,
                        "}" => braces -= 1,
                        _ => {}
                    }
                    i += 1;
                }
                return i;
            }
            _ => {}
        }
        i += 1;
    }
    i
}

/// Drop every item gated behind a test `cfg` attribute, returning the
/// library-only token stream the rules run over.
pub fn strip_test_gated(toks: Vec<Tok>) -> Vec<Tok> {
    let mut out: Vec<Tok> = Vec::with_capacity(toks.len());
    let mut i = 0usize;
    while i < toks.len() {
        if toks[i].text == "#" {
            let mut j = i + 1;
            if toks.get(j).map(|t| t.text.as_str()) == Some("!") {
                j += 1;
            }
            if toks.get(j).map(|t| t.text.as_str()) == Some("[") {
                let mut depth = 1usize;
                let mut k = j + 1;
                while k < toks.len() && depth > 0 {
                    match toks[k].text.as_str() {
                        "[" => depth += 1,
                        "]" => depth -= 1,
                        _ => {}
                    }
                    k += 1;
                }
                let attr_end = k.saturating_sub(1);
                if is_test_cfg(&toks[j + 1..attr_end]) {
                    i = skip_item(&toks, k);
                    continue;
                }
                out.extend(toks[i..k].iter().cloned());
                i = k;
                continue;
            }
        }
        out.push(toks[i].clone());
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src).toks.into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn strings_and_comments_are_stripped() {
        let toks = idents(
            "let x = \"HashMap inside\"; // HashMap in comment\n/* HashMap\nblock */ let y = 1;",
        );
        assert!(!toks.iter().any(|t| t == "HashMap"), "{toks:?}");
        assert!(toks.contains(&"x".to_string()));
        assert!(toks.contains(&"y".to_string()));
    }

    #[test]
    fn raw_and_byte_strings() {
        let toks = idents("let a = r#\"spawn \" inner\"#; let b = br\"spawn\"; let c = b\"x\\\"y\";");
        assert!(!toks.iter().any(|t| t == "spawn"), "{toks:?}");
        assert!(toks.contains(&"c".to_string()));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let toks = idents("fn f<'a>(x: &'a str) { let c = 'x'; let q = '\\''; let u = '\\u{1F600}'; }");
        assert!(toks.contains(&"a".to_string())); // lifetime name survives
        assert!(toks.contains(&"str".to_string()));
        // char contents never leak as tokens
        assert!(!toks.iter().any(|t| t == "1F600"));
    }

    #[test]
    fn byte_char_literals() {
        let toks = idents("if c == b'{' || c == b'\\t' { x(); }");
        assert!(toks.contains(&"x".to_string()));
        assert_eq!(toks.iter().filter(|t| t.as_str() == "{").count(), 1);
    }

    #[test]
    fn line_numbers() {
        let l = lex("a\nb\n  c");
        let lines: Vec<usize> = l.toks.iter().map(|t| t.line).collect();
        assert_eq!(lines, vec![1, 2, 3]);
    }

    #[test]
    fn double_colon_is_one_token() {
        let toks = idents("std::thread::spawn");
        assert_eq!(toks, vec!["std", "::", "thread", "::", "spawn"]);
    }

    #[test]
    fn numbers_do_not_eat_ranges() {
        let toks = idents("for i in 0..n { let x = 1.5; let h = 0xFF; }");
        assert!(toks.contains(&"0".to_string()));
        assert!(toks.contains(&"1.5".to_string()));
        assert!(toks.contains(&"0xFF".to_string()));
        assert_eq!(toks.iter().filter(|t| t.as_str() == ".").count(), 2);
    }

    #[test]
    fn suppression_parsing() {
        let l = lex("// pallas-lint: allow(panic-in-lib, keeps worker panics loud)\nx.unwrap();");
        assert_eq!(l.suppressions.len(), 1);
        let s = &l.suppressions[0];
        assert_eq!(s.line, 1);
        assert_eq!(s.rule, "panic-in-lib");
        assert_eq!(s.reason, "keeps worker panics loud");
    }

    #[test]
    fn doc_and_prose_mentions_are_not_suppressions() {
        let l = lex(
            "/// Use `// pallas-lint: allow(rule, reason)` to suppress.\n// see pallas-lint: allow(x, y) above\n",
        );
        assert!(l.suppressions.is_empty(), "{:?}", l.suppressions);
    }

    #[test]
    fn suppression_without_reason_or_malformed() {
        let l = lex("// pallas-lint: allow(panic-in-lib)\n// pallas-lint allow broken\n");
        assert_eq!(l.suppressions.len(), 2);
        assert_eq!(l.suppressions[0].rule, "panic-in-lib");
        assert!(l.suppressions[0].reason.is_empty());
        assert!(l.suppressions[1].rule.is_empty());
    }

    #[test]
    fn cfg_test_items_are_stripped() {
        let src = "fn lib() { a(); }\n#[cfg(test)]\nmod tests { fn t() { x.unwrap(); } }\nfn tail() { b(); }";
        let toks = strip_test_gated(lex(src).toks);
        let texts: Vec<&str> = toks.iter().map(|t| t.text.as_str()).collect();
        assert!(!texts.contains(&"unwrap"));
        assert!(texts.contains(&"lib"));
        assert!(texts.contains(&"tail"));
    }

    #[test]
    fn cfg_all_test_feature_is_stripped_but_not_cfg_feature() {
        let src = "#[cfg(all(test, feature = \"pjrt\"))]\nmod tests { fn t() { x.unwrap(); } }\n#[cfg(feature = \"pjrt\")]\nfn real() { keepme(); }";
        let toks = strip_test_gated(lex(src).toks);
        let texts: Vec<&str> = toks.iter().map(|t| t.text.as_str()).collect();
        assert!(!texts.contains(&"unwrap"));
        assert!(texts.contains(&"keepme"));
    }

    #[test]
    fn cfg_not_test_is_kept() {
        let src = "#[cfg(not(test))]\nfn real() { keepme(); }";
        let toks = strip_test_gated(lex(src).toks);
        assert!(toks.iter().any(|t| t.text == "keepme"));
    }

    #[test]
    fn cfg_test_semicolon_item() {
        let src = "#[cfg(test)]\nuse std::collections::HashMap;\nfn lib() {}";
        let toks = strip_test_gated(lex(src).toks);
        let texts: Vec<&str> = toks.iter().map(|t| t.text.as_str()).collect();
        assert!(!texts.contains(&"HashMap"));
        assert!(texts.contains(&"lib"));
    }

    #[test]
    fn stacked_attributes_after_cfg_test() {
        let src = "#[cfg(test)]\n#[allow(dead_code)]\nfn t() { x.unwrap(); }\nfn lib() {}";
        let toks = strip_test_gated(lex(src).toks);
        let texts: Vec<&str> = toks.iter().map(|t| t.text.as_str()).collect();
        assert!(!texts.contains(&"unwrap"));
        assert!(texts.contains(&"lib"));
    }
}
