//! `pallas-lint`: in-tree determinism & robustness static analysis.
//!
//! The reproduction's headline guarantee — bit-identical parallel runs,
//! seed-driven faults, stable `KnowledgeBase::digest` — rests on coding
//! invariants no compiler checks: deterministic-iteration containers,
//! one thread pool, one clock, one seeded RNG, no library panics, and
//! fault code that only touches sim state through the hook API.  This
//! module turns those invariants into machine-checked rules:
//!
//! | code | id                  | invariant                                     |
//! |------|---------------------|-----------------------------------------------|
//! | R1   | `nondet-iteration`  | no `HashMap`/`HashSet`                        |
//! | R2   | `ad-hoc-thread`     | no `thread::spawn`/`scope` outside `util::par`|
//! | R3   | `ad-hoc-clock`      | no `Instant`/`SystemTime` outside `util::timer`|
//! | R4   | `ad-hoc-entropy`    | no OS-entropy RNG outside `util::rng`         |
//! | R5   | `panic-in-lib`      | no `.unwrap()`/`.expect()`/`panic!` in lib code|
//! | R6   | `fault-hook-bypass` | faults use the hook API, never `&mut` sim state|
//!
//! Violations can be suppressed in place with a mandatory reason:
//!
//! ```text
//! // pallas-lint: allow(rule-id, why this one is sound)
//! ```
//!
//! The comment covers its own line and the next.  A missing reason or
//! unknown rule id is itself reported (`bad-suppression`).  Pre-existing
//! debt lives in `rust/lint-baseline.txt` (see [`baseline`]) and only
//! ratchets down.  The scanner is exposed as the `pallas-lint` binary
//! (`src/bin/pallas_lint.rs`), gated in `scripts/ci.sh`.

pub mod baseline;
pub mod lexer;
pub mod rules;

use std::path::{Path, PathBuf};

use crate::util::err::{Context, Result};

/// One lint finding.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Violation {
    /// Rule id (`panic-in-lib`, ... or `bad-suppression`).
    pub rule: &'static str,
    /// Crate-relative `/`-separated path (`src/offline/cache.rs`).
    pub path: String,
    /// 1-based source line.
    pub line: usize,
    /// Short token-level excerpt of what matched.
    pub snippet: String,
}

/// Scan one file's source, applying every rule, honoring suppressions,
/// and reporting invalid suppressions.  `path` must be the normalized
/// crate-relative path the rules key their exemptions on.
pub fn scan_source(path: &str, source: &str) -> Vec<Violation> {
    let lexed = lexer::lex(source);
    let lib_toks = lexer::strip_test_gated(lexed.toks);

    let mut raw: Vec<Violation> = Vec::new();
    for rule in rules::registry() {
        for (line, snippet) in (rule.matcher)(path, &lib_toks) {
            raw.push(Violation {
                rule: rule.id,
                path: path.to_string(),
                line,
                snippet,
            });
        }
    }

    let mut out: Vec<Violation> = Vec::new();
    let mut valid: Vec<&lexer::Suppression> = Vec::new();
    for s in &lexed.suppressions {
        if s.rule.is_empty() || !rules::is_known_rule(&s.rule) {
            out.push(Violation {
                rule: rules::SUPPRESSION_RULE,
                path: path.to_string(),
                line: s.line,
                snippet: if s.rule.is_empty() {
                    "malformed pallas-lint comment".to_string()
                } else {
                    format!("unknown rule id `{}`", s.rule)
                },
            });
        } else if s.reason.is_empty() {
            out.push(Violation {
                rule: rules::SUPPRESSION_RULE,
                path: path.to_string(),
                line: s.line,
                snippet: format!("allow({}) without a reason", s.rule),
            });
        } else {
            valid.push(s);
        }
    }

    for v in raw {
        let suppressed = valid
            .iter()
            .any(|s| s.rule == v.rule && (s.line == v.line || s.line + 1 == v.line));
        if !suppressed {
            out.push(v);
        }
    }
    out.sort_by(|a, b| (a.line, a.rule).cmp(&(b.line, b.rule)));
    out
}

/// Recursively collect `.rs` files under `dir` in sorted order.
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let mut entries: Vec<PathBuf> = Vec::new();
    for entry in
        std::fs::read_dir(dir).with_context(|| format!("read dir {}", dir.display()))?
    {
        let entry = entry.with_context(|| format!("read dir {}", dir.display()))?;
        entries.push(entry.path());
    }
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(())
}

/// Scan every `.rs` file under `root` (typically `rust/src`).  Paths in
/// the returned violations are normalized to `src/...` with `/`
/// separators regardless of the invocation directory, so baseline
/// entries are stable.
pub fn scan_tree(root: &Path) -> Result<Vec<Violation>> {
    let prefix = root
        .file_name()
        .map(|s| s.to_string_lossy().into_owned())
        .unwrap_or_else(|| "src".to_string());
    let mut files: Vec<PathBuf> = Vec::new();
    collect_rs(root, &mut files)?;
    let mut out: Vec<Violation> = Vec::new();
    for f in &files {
        let rel_part = f.strip_prefix(root).unwrap_or(f);
        let mut rel = prefix.clone();
        for comp in rel_part.components() {
            rel.push('/');
            rel.push_str(&comp.as_os_str().to_string_lossy());
        }
        let src = std::fs::read_to_string(f)
            .with_context(|| format!("read source {}", f.display()))?;
        out.extend(scan_source(&rel, &src));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suppression_covers_same_and_next_line() {
        let src = "fn f(x: Option<u32>) -> u32 {\n\
                   // pallas-lint: allow(panic-in-lib, checked by caller)\n\
                   x.unwrap()\n\
                   }\n\
                   fn g(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let vs = scan_source("src/demo.rs", src);
        assert_eq!(vs.len(), 1, "{vs:?}");
        assert_eq!(vs[0].line, 5);
    }

    #[test]
    fn suppression_does_not_leak_across_rules() {
        let src = "// pallas-lint: allow(panic-in-lib, wrong rule)\nuse std::collections::HashMap;\n";
        let vs = scan_source("src/demo.rs", src);
        assert_eq!(vs.len(), 1);
        assert_eq!(vs[0].rule, "nondet-iteration");
    }

    #[test]
    fn reasonless_suppression_is_flagged_and_inert() {
        let src = "// pallas-lint: allow(panic-in-lib)\nfn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let vs = scan_source("src/demo.rs", src);
        let rules: Vec<&str> = vs.iter().map(|v| v.rule).collect();
        assert!(rules.contains(&"bad-suppression"), "{vs:?}");
        assert!(rules.contains(&"panic-in-lib"), "{vs:?}");
    }

    #[test]
    fn unknown_rule_suppression_is_flagged() {
        let src = "// pallas-lint: allow(no-such-rule, because)\nfn f() {}\n";
        let vs = scan_source("src/demo.rs", src);
        assert_eq!(vs.len(), 1);
        assert_eq!(vs[0].rule, "bad-suppression");
    }
}
