//! The `pallas-lint` rule registry.
//!
//! Each rule is a pure function over the library-only token stream (see
//! [`crate::analysis::lexer::strip_test_gated`]) plus the file's
//! crate-relative path (`src/...`, always `/`-separated).  Rules return
//! `(line, snippet)` pairs; suppression and baseline filtering happen
//! in [`crate::analysis::scan_source`].

use crate::analysis::lexer::Tok;

/// One lint rule.
pub struct Rule {
    /// Stable kebab-case id used in suppressions and the baseline.
    pub id: &'static str,
    /// Short code shown in human output (R1..R6).
    pub code: &'static str,
    /// One-line description for `--list-rules`.
    pub summary: &'static str,
    pub matcher: fn(&str, &[Tok]) -> Vec<(usize, String)>,
}

/// Pseudo-rule id for invalid suppression comments (unknown rule id or
/// missing reason).  Not suppressible and never baselined.
pub const SUPPRESSION_RULE: &str = "bad-suppression";

/// Token text at `i`, or `""` past the end.
fn txt(toks: &[Tok], i: usize) -> &str {
    toks.get(i).map(|t| t.text.as_str()).unwrap_or("")
}

fn is_ident(s: &str) -> bool {
    s.chars()
        .next()
        .map(|c| c == '_' || c.is_ascii_alphabetic())
        .unwrap_or(false)
}

/// R1: hash containers iterate in randomized order (`RandomState`),
/// which poisons digests, serialized artifacts and eviction decisions.
/// `BTreeMap`/`BTreeSet` (or a `Vec`) are the sanctioned containers.
fn nondet_iteration(_path: &str, toks: &[Tok]) -> Vec<(usize, String)> {
    toks.iter()
        .filter(|t| t.text == "HashMap" || t.text == "HashSet")
        .map(|t| (t.line, t.text.clone()))
        .collect()
}

/// R2: ad-hoc threads bypass the deterministic pool's ordered
/// reduction and nested-parallelism guard; all fan-out goes through
/// `util::par`.
fn ad_hoc_thread(path: &str, toks: &[Tok]) -> Vec<(usize, String)> {
    if path == "src/util/par.rs" {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        if t.text == "thread"
            && txt(toks, i + 1) == "::"
            && matches!(txt(toks, i + 2), "spawn" | "scope")
        {
            out.push((t.line, format!("thread::{}", txt(toks, i + 2))));
        }
        if t.text == "." && txt(toks, i + 1) == "spawn" && txt(toks, i + 2) == "(" {
            out.push((toks[i + 1].line, ".spawn(...)".to_string()));
        }
    }
    out
}

/// R3: wall-clock reads make runs time-dependent; all timing goes
/// through `util::timer` so experiments stay replayable.
fn ad_hoc_clock(path: &str, toks: &[Tok]) -> Vec<(usize, String)> {
    if path == "src/util/timer.rs" {
        return Vec::new();
    }
    toks.iter()
        .filter(|t| t.text == "Instant" || t.text == "SystemTime")
        .map(|t| (t.line, t.text.clone()))
        .collect()
}

/// R4: entropy must come from the in-tree seeded `util::rng::Rng`;
/// OS-entropy constructors and external RNG crates break seed-driven
/// reproducibility.  (Seeded `Rng::new(seed)` is the sanctioned path
/// and is not flagged.)
fn ad_hoc_entropy(path: &str, toks: &[Tok]) -> Vec<(usize, String)> {
    if path == "src/util/rng.rs" {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        if matches!(
            t.text.as_str(),
            "thread_rng" | "from_entropy" | "getrandom" | "RandomState"
        ) {
            out.push((t.line, t.text.clone()));
        }
        if t.text == "rand" && txt(toks, i + 1) == "::" {
            out.push((t.line, "rand::".to_string()));
        }
    }
    out
}

/// R5: library code must surface failures as `util::err::Result` (via
/// the `Context` trait / `bail!`), never panic.  `src/main.rs` and
/// `src/bin/**` are exempt (top-level binaries may crash on bad input);
/// test-gated code was already stripped from the token stream.
fn panic_in_lib(path: &str, toks: &[Tok]) -> Vec<(usize, String)> {
    if path == "src/main.rs" || path.starts_with("src/bin/") {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        if t.text == "."
            && matches!(txt(toks, i + 1), "unwrap" | "expect")
            && txt(toks, i + 2) == "("
        {
            out.push((toks[i + 1].line, format!(".{}(...)", txt(toks, i + 1))));
        }
        if matches!(t.text.as_str(), "panic" | "todo" | "unimplemented")
            && txt(toks, i + 1) == "!"
        {
            out.push((t.line, format!("{}!", t.text)));
        }
    }
    out
}

/// Sim-state types fault code may only touch through the hook API
/// (`FaultInjector` / recovery plans), never via `&mut`.
const SIM_STATE_TYPES: [&str; 7] = [
    "NetProfile",
    "LoadState",
    "SimEnv",
    "MultiUserSim",
    "TrafficProcess",
    "ThroughputModel",
    "Dataset",
];

/// R6: fault code bypassing the hook API — reaching into the sim
/// engine modules or taking `&mut` references to sim-state types —
/// would make fault effects depend on call order instead of the seeded
/// fault plan.
fn fault_hook_bypass(path: &str, toks: &[Tok]) -> Vec<(usize, String)> {
    if !path.starts_with("src/faults/") {
        return Vec::new();
    }
    let mut out = Vec::new();
    for (i, t) in toks.iter().enumerate() {
        if t.text == "crate"
            && txt(toks, i + 1) == "::"
            && txt(toks, i + 2) == "sim"
            && txt(toks, i + 3) == "::"
            && matches!(txt(toks, i + 4), "engine" | "multiuser")
        {
            out.push((t.line, format!("crate::sim::{}", txt(toks, i + 4))));
        }
        if t.text == "&" && txt(toks, i + 1) == "mut" {
            // walk the path that follows (`a :: b :: Type`) and check
            // the last identifier against the protected sim-state set
            let mut j = i + 2;
            let mut last: Option<usize> = None;
            while j < toks.len() {
                let s = toks[j].text.as_str();
                if s == "::" {
                    j += 1;
                    continue;
                }
                if is_ident(s) {
                    last = Some(j);
                    j += 1;
                    continue;
                }
                break;
            }
            if let Some(k) = last {
                if SIM_STATE_TYPES.contains(&toks[k].text.as_str()) {
                    out.push((toks[k].line, format!("&mut {}", toks[k].text)));
                }
            }
        }
    }
    out
}

/// The full registry, in rule-code order.
pub fn registry() -> Vec<Rule> {
    vec![
        Rule {
            id: "nondet-iteration",
            code: "R1",
            summary: "no HashMap/HashSet (randomized iteration order); use BTreeMap/BTreeSet/Vec",
            matcher: nondet_iteration,
        },
        Rule {
            id: "ad-hoc-thread",
            code: "R2",
            summary: "no thread::spawn/scope outside util::par (deterministic pool required)",
            matcher: ad_hoc_thread,
        },
        Rule {
            id: "ad-hoc-clock",
            code: "R3",
            summary: "no Instant/SystemTime outside util::timer (wall-clock breaks replay)",
            matcher: ad_hoc_clock,
        },
        Rule {
            id: "ad-hoc-entropy",
            code: "R4",
            summary: "no OS-entropy RNG construction outside util::rng (seeded Rng::new only)",
            matcher: ad_hoc_entropy,
        },
        Rule {
            id: "panic-in-lib",
            code: "R5",
            summary: "no .unwrap()/.expect()/panic! in library code; use util::err::Context",
            matcher: panic_in_lib,
        },
        Rule {
            id: "fault-hook-bypass",
            code: "R6",
            summary: "fault code must use the hook API, not mutate sim state directly",
            matcher: fault_hook_bypass,
        },
    ]
}

/// Is `id` a registered rule id (suppression target)?
pub fn is_known_rule(id: &str) -> bool {
    registry().iter().any(|r| r.id == id)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::lexer;

    fn hits(rule_id: &str, path: &str, src: &str) -> Vec<(usize, String)> {
        let toks = lexer::strip_test_gated(lexer::lex(src).toks);
        let reg = registry();
        let rule = reg.iter().find(|r| r.id == rule_id).expect("known rule");
        (rule.matcher)(path, &toks)
    }

    #[test]
    fn unwrap_variants_are_not_flagged() {
        let src = "fn f(x: Option<u32>) -> u32 { x.unwrap_or(0) + x.unwrap_or_else(|| 1) + x.unwrap_or_default() }";
        assert!(hits("panic-in-lib", "src/lib.rs", src).is_empty());
    }

    #[test]
    fn panic_path_is_not_flagged() {
        // std::panic::catch_unwind must not match `panic!`
        let src = "fn f() { let _ = std::panic::catch_unwind(|| 1); }";
        assert!(hits("panic-in-lib", "src/lib.rs", src).is_empty());
    }

    #[test]
    fn bin_paths_are_panic_exempt() {
        let src = "fn main() { foo().unwrap(); }";
        assert!(hits("panic-in-lib", "src/main.rs", src).is_empty());
        assert!(hits("panic-in-lib", "src/bin/pallas_lint.rs", src).is_empty());
        assert_eq!(hits("panic-in-lib", "src/offline/mod.rs", src).len(), 1);
    }

    #[test]
    fn seeded_rng_is_sanctioned() {
        let src = "fn f() { let mut r = Rng::new(42); let _ = r.next_f64(); }";
        assert!(hits("ad-hoc-entropy", "src/sim/engine.rs", src).is_empty());
        let bad = "fn f() { let mut r = rand::thread_rng(); }";
        assert!(!hits("ad-hoc-entropy", "src/sim/engine.rs", bad).is_empty());
    }

    #[test]
    fn fault_rule_only_fires_under_faults() {
        let src = "fn f(p: &mut crate::sim::profile::NetProfile) {}";
        assert!(!hits("fault-hook-bypass", "src/faults/engine.rs", src).is_empty());
        assert!(hits("fault-hook-bypass", "src/sim/engine.rs", src).is_empty());
    }
}
