//! `twophase` — reproduction of *"A Two-Phase Dynamic Throughput
//! Optimization Model for Big Data Transfers"* (Nine & Kosar, 2018) as a
//! three-layer Rust + JAX + Pallas stack.
//!
//! The crate is organised bottom-up (see `DESIGN.md` for the full map):
//!
//! * [`util`] — in-tree replacements for crates unavailable offline
//!   (seeded RNG, JSON, CLI parsing, stats, linear algebra, a
//!   property-testing mini-framework, a bench harness, and
//!   [`util::par`]: a deterministic scoped thread pool whose ordered
//!   reduction keeps every parallel result bit-identical to serial —
//!   `PALLAS_THREADS` overrides the worker count, `=1` is the serial
//!   path — and [`util::trace`]: a deterministic sim-time tracing +
//!   metrics layer whose JSONL export is byte-identical at any thread
//!   count);
//! * [`sim`] — the testbed substrate: a mechanistic wide-area transfer
//!   simulator (TCP streams, endpoints, background traffic, shared
//!   bottleneck links) standing in for XSEDE / DIDCLAB / Chameleon;
//! * [`faults`] — deterministic, seed-driven fault injection: a
//!   [`faults::FaultPlan`] schedules link degradation, loss bursts,
//!   RTT inflation, traffic surges and endpoint stalls, which the sim
//!   layer consumes through explicit hook points;
//! * [`logs`] — GridFTP-style historical transfer logs: schema,
//!   synthetic six-week generator, persistent store;
//! * [`offline`] — the paper's offline phase: log clustering
//!   (K-means++ / HAC + CH index), piecewise bicubic throughput
//!   surfaces, Gaussian confidence regions, Hessian maxima, sampling
//!   regions, the five-phase additive pipeline (hot loops fanned out
//!   over [`util::par`]), and [`offline::cache`]: an LRU historical
//!   tuning cache that warm-starts the online controller on repeat
//!   (network, dataset) fingerprints;
//! * [`online`] — the paper's online phase: the Adaptive Sampling
//!   Module (Algorithm 1), deviation monitoring and dynamic re-tuning;
//! * [`baselines`] — the seven comparison models of §5 (GO, SP, SC,
//!   HARP, ANN+OT, NMT, no-op) behind one [`baselines::api::Optimizer`]
//!   trait;
//! * [`runtime`] — PJRT execution of the AOT artifacts produced by
//!   `python/compile/aot.py` (HLO text via the `xla` crate) with
//!   native-math parity fallbacks;
//! * [`coordinator`] — the leader loop: request intake, sample-transfer
//!   scheduling, chunk streaming, multi-user orchestration, metrics;
//! * [`experiments`] — one driver per paper table/figure, shared by the
//!   benches in `rust/benches/` and the CLI; sweeps fan their grid
//!   cells out over [`util::par`], each cell seeded by the pure
//!   fork-per-cell rule `Rng::fork(seed, cell_idx)` so results are
//!   bit-identical at any thread count (ROADMAP §Experiment
//!   parallelism);
//! * [`analysis`] — `pallas-lint`: a token-level static scanner that
//!   machine-checks the determinism & robustness invariants the layers
//!   above rely on (rules R1–R6: deterministic containers, pooled
//!   threading, one clock, seeded entropy, no library panics,
//!   fault-hook discipline), with inline suppressions and a ratcheting
//!   baseline — run via `cargo run --bin pallas-lint`, gated in
//!   `scripts/ci.sh`.
//!
//! # Observability
//!
//! [`util::trace`] threads a deterministic trace through the transfer
//! lifecycle: `Orchestrator::set_tracer` attaches a collector, and
//! every transfer then records a per-request span plus events for
//! sampling steps and ASM convergence, alarm-level transitions,
//! fault-state changes, chunk stalls, backoff waits, cache verdicts
//! and re-tunes, alongside a counter/gauge/histogram registry.  All
//! timestamps are sim time (lint rule R3: no wall clocks), all keyed
//! state is `BTreeMap` (R1), and records are exported in scope-key
//! order with globally-assigned sequence numbers, so the JSONL dump is
//! a pure function of seeds — `tests/prop_trace.rs` proves byte
//! equality across `PALLAS_THREADS` ∈ {1, 2, 8}.  The CLI exposes it
//! as `twophase transfer --trace <path>` and `twophase trace-schema`.
//!
//! # Fault model & recovery
//!
//! The fault subsystem makes the stack's resilience claims testable.
//! A [`faults::FaultPlan`] is generated once from a seed
//! ([`faults::FaultPlanConfig`] sets horizon, event rate, intensity)
//! and replayed read-only, so identically-seeded runs experience the
//! identical storm.  The sim layer consumes it through hooks —
//! [`sim::tcp::stream_rate_under_fault`],
//! [`sim::link::share_bottleneck_under_fault`], and
//! `SimEnv::with_faults` / `MultiUserSim::with_faults` — never by
//! ad-hoc state mutation.  Recovery lives one layer up: the
//! coordinator retries failed chunks under the scheduler's
//! [`coordinator::scheduler::RetryPolicy`] (exponential backoff,
//! capped), resumes from per-chunk checkpoints so completed bytes are
//! never re-sent, and after a confirmed fault re-queries the knowledge
//! base and restarts the ASM bisection — the paper's §4.2 re-tuning
//! path, surfaced through [`online::monitor::AlarmLevel`] and
//! `DynamicTuner::rearm`.  `experiments::robustness` sweeps fault
//! intensity and reports each model's recovered-throughput fraction.

pub mod analysis;
pub mod baselines;
pub mod coordinator;
pub mod experiments;
pub mod faults;
pub mod logs;
pub mod offline;
pub mod online;
pub mod runtime;
pub mod sim;
pub mod util;

/// Protocol parameter triple the whole paper optimizes: concurrency,
/// parallelism, pipelining (§2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Params {
    /// Concurrency: number of transfer server processes.
    pub cc: u32,
    /// Parallelism: TCP streams per process.
    pub p: u32,
    /// Pipelining: outstanding file-request queue depth.
    pub pp: u32,
}

impl Params {
    pub const fn new(cc: u32, p: u32, pp: u32) -> Self {
        Self { cc, p, pp }
    }

    /// Total data streams opened by this setting (cc × p, §2).
    pub fn total_streams(&self) -> u32 {
        self.cc * self.p
    }

    /// The "no optimization" default of §5.4: cc = p = pp = 1.
    pub const DEFAULT: Params = Params::new(1, 1, 1);

    /// Clamp each component into `[1, cap]`.
    pub fn clamp(&self, cap: u32) -> Params {
        Params::new(
            self.cc.clamp(1, cap),
            self.p.clamp(1, cap),
            self.pp.clamp(1, cap),
        )
    }
}

impl std::fmt::Display for Params {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "(cc={}, p={}, pp={})", self.cc, self.p, self.pp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn params_total_streams() {
        assert_eq!(Params::new(4, 2, 8).total_streams(), 8);
        assert_eq!(Params::DEFAULT.total_streams(), 1);
    }

    #[test]
    fn params_clamp() {
        assert_eq!(Params::new(0, 99, 7).clamp(32), Params::new(1, 32, 7));
    }

    #[test]
    fn params_display() {
        assert_eq!(Params::new(2, 3, 4).to_string(), "(cc=2, p=3, pp=4)");
    }
}
