//! `twophase` CLI — the leader entrypoint.
//!
//! ```text
//! twophase info                               # profiles + artifact status
//! twophase gen-logs  --profile xsede --days 14 --out logs.jsonl
//! twophase offline   --logs logs.jsonl [--pjrt] [--out summary.json]
//! twophase transfer  --profile xsede --files 64 --avg-mb 512 \
//!                    [--model asm|harp|annot|go|sp|sc|nmt|noopt] [--peak]
//! twophase multiuser [--users 4] [--model asm] [--duration 600]
//! twophase experiment <table1|fig1|fig4a|fig4b|fig5|fig6|fig7|fig8|fig9|robustness|all>
//! twophase trace-schema <trace.jsonl> [--golden scripts/trace-schema.golden]
//! ```
//!
//! `transfer` accepts `--trace <path>` to dump the deterministic
//! sim-time trace of the run as JSONL (see `util::trace`);
//! `trace-schema` prints a trace's schema (field names per record
//! kind) and, with `--golden`, verifies it against a checked-in
//! schema file (CI smoke).

use std::sync::Arc;
use twophase::bail;
use twophase::baselines::ann_ot::AnnOtModel;
use twophase::baselines::api::OptimizerKind;
use twophase::baselines::static_ann::StaticAnnModel;
use twophase::coordinator::orchestrator::{
    Orchestrator, OrchestratorConfig, TransferRequest,
};
use twophase::experiments;
use twophase::logs::generator::{generate_history, GeneratorConfig};
use twophase::logs::store::LogStore;
use twophase::offline::pipeline::{KnowledgeBase, OfflineConfig};
use twophase::offline::surface::NativeSurfaceBackend;
use twophase::runtime::accel::PjrtSurfaceBackend;
use twophase::runtime::engine::Engine;
use twophase::sim::dataset::Dataset;
use twophase::sim::profile::NetProfile;
use twophase::util::cli::Args;
use twophase::util::err::{Context, Result};

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand.as_deref() {
        Some("info") => cmd_info(),
        Some("gen-logs") => cmd_gen_logs(&args),
        Some("offline") => cmd_offline(&args),
        Some("transfer") => cmd_transfer(&args),
        Some("multiuser") => cmd_multiuser(&args),
        Some("experiment") => cmd_experiment(&args),
        Some("trace-schema") => cmd_trace_schema(&args),
        _ => {
            print_usage();
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_usage() {
    println!(
        "twophase — Two-Phase Dynamic Throughput Optimization (Nine & Kosar 2018)\n\
         subcommands: info | gen-logs | offline | transfer | multiuser | experiment | trace-schema\n\
         run with no flags for defaults; see README.md for details"
    );
}

fn profile_arg(args: &Args) -> Result<NetProfile> {
    let name = args.get_or("profile", "xsede");
    NetProfile::by_name(name).with_context(|| format!("unknown profile '{name}'"))
}

fn model_arg(args: &Args) -> Result<OptimizerKind> {
    Ok(match args.get_or("model", "asm") {
        "asm" => OptimizerKind::Asm,
        "harp" => OptimizerKind::Harp,
        "annot" => OptimizerKind::AnnOt,
        "go" => OptimizerKind::Globus,
        "sp" => OptimizerKind::StaticAnn,
        "sc" => OptimizerKind::SingleChunk,
        "nmt" => OptimizerKind::NelderMead,
        "noopt" => OptimizerKind::NoOpt,
        other => bail!("unknown model '{other}'"),
    })
}

fn cmd_info() -> Result<()> {
    experiments::table1::run();
    match Engine::try_default() {
        Some(e) => println!(
            "PJRT artifacts: loaded ({} artifacts, platform {})",
            e.manifest.artifacts.len(),
            e.platform()
        ),
        None => println!("PJRT artifacts: not built (run `make artifacts`)"),
    }
    Ok(())
}

fn cmd_gen_logs(args: &Args) -> Result<()> {
    let profile = profile_arg(args)?;
    let cfg = GeneratorConfig {
        days: args.get_f64("days", 42.0),
        transfers_per_hour: args.get_f64("rate", 8.0),
        seed: args.get_u64("seed", 0xB16_DA7A),
    };
    let logs = generate_history(&profile, &cfg);
    let out = args.get_or("out", "logs.jsonl");
    let mut store = LogStore::open(out)?;
    store.append(&logs)?;
    println!(
        "wrote {} log entries for {} ({} days) to {out}",
        logs.len(),
        profile.name,
        cfg.days
    );
    Ok(())
}

fn load_logs(args: &Args) -> Result<Vec<twophase::logs::schema::LogEntry>> {
    match args.get("logs") {
        Some(path) => {
            let store = LogStore::open(path)?;
            if store.is_empty() {
                bail!("{path} contains no log entries");
            }
            Ok(store.entries().to_vec())
        }
        None => {
            // synthesize a default corpus across all profiles
            let mut logs = Vec::new();
            for p in NetProfile::all() {
                logs.extend(generate_history(
                    &p,
                    &GeneratorConfig {
                        days: args.get_f64("days", 14.0),
                        transfers_per_hour: 8.0,
                        seed: 0xB16_DA7A,
                    },
                ));
            }
            Ok(logs)
        }
    }
}

fn cmd_offline(args: &Args) -> Result<()> {
    let logs = load_logs(args)?;
    let cfg = OfflineConfig::default();
    let kb = if args.flag("pjrt") {
        let engine = Engine::try_default()
            .context("--pjrt requested but artifacts are not built (make artifacts)")?;
        let backend = PjrtSurfaceBackend::new(engine);
        KnowledgeBase::build(
            logs,
            cfg,
            &backend,
            &twophase::offline::kmeans::NativeKmeans,
        )
    } else {
        KnowledgeBase::build(
            logs,
            cfg,
            &NativeSurfaceBackend,
            &twophase::offline::kmeans::NativeKmeans,
        )
    };
    let summary = kb.summary_json();
    println!("{summary}");
    if let Some(out) = args.get("out") {
        std::fs::write(out, summary.to_string())?;
        println!("summary written to {out}");
    }
    Ok(())
}

fn build_orchestrator(args: &Args) -> Result<Orchestrator> {
    let logs = load_logs(args)?;
    let kb = Arc::new(KnowledgeBase::build_native(
        logs.clone(),
        OfflineConfig::default(),
    ));
    let sp = Arc::new(StaticAnnModel::train(&logs, 32, 0xE1));
    let annot = Arc::new(AnnOtModel::train(&logs, 32, 0xE2));
    Orchestrator::new(kb, sp, annot, OrchestratorConfig::default())
}

fn cmd_transfer(args: &Args) -> Result<()> {
    let profile = profile_arg(args)?;
    let model = model_arg(args)?;
    let dataset = Dataset::new(
        args.get_u64("files", 64),
        args.get_f64("avg-mb", 512.0),
    );
    let orch = build_orchestrator(args)?;
    let req = TransferRequest {
        id: 0,
        profile,
        dataset,
        model,
        seed: args.get_u64("seed", 7),
        phase_s: if args.flag("peak") {
            experiments::common::PEAK_PHASE_S
        } else {
            experiments::common::OFFPEAK_PHASE_S
        },
    };
    let tracer = args
        .get("trace")
        .map(|_| Arc::new(twophase::util::trace::Tracer::new()));
    if let Some(t) = &tracer {
        orch.set_tracer(Some(Arc::clone(t)));
    }
    let r = orch.execute(&req);
    println!(
        "model={} network={} total={:.0} MB duration={:.1}s",
        r.model, r.network, r.total_mb, r.duration_s
    );
    println!(
        "avg={:.1} Mbps steady={:.1} Mbps samples={} param-changes={} stalled={} final={}",
        r.avg_throughput_mbps,
        r.steady_throughput_mbps,
        r.sample_transfers,
        r.param_changes,
        r.stalled_chunks,
        r.final_params
    );
    if let (Some(pred), Some(acc)) = (r.predicted_mbps, r.accuracy_pct) {
        println!("predicted={pred:.1} Mbps accuracy={acc:.1}%");
    }
    if let (Some(tracer), Some(path)) = (tracer, args.get("trace")) {
        tracer.write_jsonl(path)?;
        println!("{} -> {path}", tracer.summary());
    }
    Ok(())
}

fn cmd_trace_schema(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .context("usage: twophase trace-schema <trace.jsonl> [--golden <schema file>]")?;
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    let schema = twophase::util::trace::schema_of_jsonl(&text)
        .with_context(|| format!("parsing {path}"))?;
    match args.get("golden") {
        None => print!("{schema}"),
        Some(golden_path) => {
            let golden = std::fs::read_to_string(golden_path)
                .with_context(|| format!("reading {golden_path}"))?;
            if schema != golden {
                eprintln!("--- expected ({golden_path})\n{golden}--- actual ({path})\n{schema}");
                bail!("trace schema drifted from {golden_path}");
            }
            println!("trace schema matches {golden_path}");
        }
    }
    Ok(())
}

fn cmd_multiuser(args: &Args) -> Result<()> {
    std::env::set_var("TWOPHASE_DAYS", args.get_or("days", "14"));
    let _ = experiments::fig9::run();
    // documented; fig9 sweeps user counts {1,2,4,8} with the paper's 4
    // as the headline — the flag stays accepted for compatibility
    let _ = args.get_usize("users", 4);
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let which = args
        .positional
        .first()
        .map(|s| s.as_str())
        .unwrap_or("all");
    let run_one = |name: &str| -> Result<()> {
        match name {
            "table1" => {
                experiments::table1::run();
            }
            "fig1" => {
                experiments::fig1::run();
            }
            "fig4a" => {
                experiments::fig4a::run();
            }
            "fig4b" => {
                experiments::fig4b::run();
            }
            "fig5" => {
                experiments::fig5::run();
            }
            "fig6" => {
                experiments::fig6::run();
            }
            "fig7" => {
                experiments::fig7::run();
            }
            "fig8" => {
                experiments::fig8::run();
            }
            "fig9" | "fig2" | "fig10" => {
                experiments::fig9::run();
            }
            "robustness" => {
                experiments::robustness::run();
            }
            other => bail!("unknown experiment '{other}'"),
        }
        Ok(())
    };
    if which == "all" {
        for name in [
            "table1", "fig1", "fig4a", "fig4b", "fig5", "fig6", "fig7", "fig8", "fig9",
            "robustness",
        ] {
            println!("\n=== {name} ===");
            run_one(name)?;
        }
    } else {
        run_one(which)?;
    }
    Ok(())
}
