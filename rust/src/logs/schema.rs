//! GridFTP-style transfer log records.
//!
//! One entry per completed (chunk) transfer, carrying everything Eq 1
//! conditions on: endpoints/network (`rtt`, `bw`), dataset (`f_avg`,
//! `n`), protocol parameters (`cc`, `p`, `pp`), the achieved throughput
//! and a timestamp.  The load-intensity tag is *not* observed by the
//! offline phase on real logs; the generator records the true value so
//! tests can validate the load-bucket reconstruction.

use crate::util::json::Value;
use crate::Params;

/// One historical transfer observation.
#[derive(Debug, Clone, PartialEq)]
pub struct LogEntry {
    /// Seconds since the epoch of the log window.
    pub timestamp_s: f64,
    /// Network profile name (stands in for the endpoint pair).
    pub network: String,
    pub rtt_s: f64,
    pub bandwidth_mbps: f64,
    pub avg_file_mb: f64,
    pub n_files: u64,
    pub params: Params,
    pub throughput_mbps: f64,
    /// True normalized external-load intensity at transfer time.
    /// Hidden ground truth: offline reconstructs its own buckets from
    /// (timestamp, throughput); experiments use this for validation.
    pub true_load: f64,
}

impl LogEntry {
    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("ts", Value::Num(self.timestamp_s)),
            ("net", Value::str(self.network.clone())),
            ("rtt", Value::Num(self.rtt_s)),
            ("bw", Value::Num(self.bandwidth_mbps)),
            ("favg", Value::Num(self.avg_file_mb)),
            ("nf", Value::Num(self.n_files as f64)),
            ("cc", Value::Num(self.params.cc as f64)),
            ("p", Value::Num(self.params.p as f64)),
            ("pp", Value::Num(self.params.pp as f64)),
            ("th", Value::Num(self.throughput_mbps)),
            ("load", Value::Num(self.true_load)),
        ])
    }

    pub fn from_json(v: &Value) -> Option<LogEntry> {
        Some(LogEntry {
            timestamp_s: v.get("ts").as_f64()?,
            network: v.get("net").as_str()?.to_string(),
            rtt_s: v.get("rtt").as_f64()?,
            bandwidth_mbps: v.get("bw").as_f64()?,
            avg_file_mb: v.get("favg").as_f64()?,
            n_files: v.get("nf").as_u64()?,
            params: Params::new(
                v.get("cc").as_u64()? as u32,
                v.get("p").as_u64()? as u32,
                v.get("pp").as_u64()? as u32,
            ),
            throughput_mbps: v.get("th").as_f64()?,
            true_load: v.get("load").as_f64()?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry() -> LogEntry {
        LogEntry {
            timestamp_s: 123.5,
            network: "xsede".into(),
            rtt_s: 0.04,
            bandwidth_mbps: 10_000.0,
            avg_file_mb: 64.0,
            n_files: 500,
            params: Params::new(4, 2, 8),
            throughput_mbps: 3211.75,
            true_load: 0.4,
        }
    }

    #[test]
    fn json_roundtrip() {
        let e = entry();
        let v = e.to_json();
        let back = LogEntry::from_json(&v).unwrap();
        assert_eq!(e, back);
    }

    #[test]
    fn malformed_json_is_none() {
        assert!(LogEntry::from_json(&Value::Null).is_none());
        let incomplete = Value::obj(vec![("ts", Value::Num(1.0))]);
        assert!(LogEntry::from_json(&incomplete).is_none());
        // fractional file count is invalid
        let mut v = entry().to_json();
        if let Value::Obj(ref mut m) = v {
            m.insert("nf".into(), Value::Num(2.5));
        }
        assert!(LogEntry::from_json(&v).is_none());
    }
}
