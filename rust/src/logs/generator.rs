//! Synthetic six-week GridFTP history.
//!
//! Transfers arrive as a Poisson process over the log window; each
//! picks a dataset class, a dataset, and protocol parameters from the
//! grid users actually try (GridFTP users and tools overwhelmingly use
//! small powers of two), then records the throughput the simulator
//! gives under the background load at that instant.
//!
//! The parameter *grid* matters: the offline phase builds spline knots
//! from the distinct (p, cc) values present in the logs, exactly like
//! the paper's surfaces over historical observations.
//!
//! Generation fans out per *day* over [`crate::util::par`]: each day
//! forks its own arrival and traffic RNG streams via [`Rng::fork`] (a
//! pure function of `(seed, day)`), so the output is bit-identical for
//! any `PALLAS_THREADS` setting — `tests/prop_history_parallel.rs`
//! proves 1/2/8.  The split is exact, not approximate: Poisson
//! arrivals are memoryless, so restarting the exponential gap clock at
//! each midnight yields the same process as one continuous stream, and
//! the diurnal load component depends only on absolute time.

use crate::logs::schema::LogEntry;
use crate::sim::dataset::{Dataset, FileSizeClass};
use crate::sim::profile::NetProfile;
use crate::sim::traffic::TrafficProcess;
use crate::sim::transfer::ThroughputModel;
use crate::util::par;
use crate::util::rng::Rng;
use crate::Params;

/// Parameter values observed in the wild (and thus in our logs); these
/// become the spline knots of the offline surfaces.
pub const PARAM_GRID: [u32; 8] = [1, 2, 4, 6, 8, 12, 16, 32];
/// Pipelining values users try.
pub const PP_GRID: [u32; 5] = [1, 4, 8, 16, 32];

/// Log-generation configuration.
#[derive(Debug, Clone)]
pub struct GeneratorConfig {
    /// Length of the log window in days (paper: six weeks = 42).
    pub days: f64,
    /// Mean transfers per hour across all users of the pair.
    pub transfers_per_hour: f64,
    /// Random seed (quoted in EXPERIMENTS.md).
    pub seed: u64,
}

impl Default for GeneratorConfig {
    fn default() -> Self {
        GeneratorConfig {
            days: 42.0,
            transfers_per_hour: 6.0,
            seed: 0xB16_DA7A,
        }
    }
}

/// Stream tags for the per-day [`Rng::fork`] parents, so the arrival
/// and traffic streams of a day can never alias each other.
const ARRIVAL_STREAM: u64 = 0x6c6f67; // "log"
const TRAFFIC_STREAM: u64 = 0x74726166; // "traf"

/// Generate a history for one network profile.
///
/// Days fan out over the deterministic pool; entries come back
/// concatenated in day order, so timestamps stay strictly increasing
/// and the bytes are identical to a serial run.
pub fn generate_history(profile: &NetProfile, cfg: &GeneratorConfig) -> Vec<LogEntry> {
    let horizon_s = cfg.days * 86_400.0;
    if !(horizon_s > 0.0) {
        return Vec::new();
    }
    let model = ThroughputModel::new(profile.clone());
    let n_days = (cfg.days.ceil() as usize).max(1);
    let per_day = par::par_indices(n_days, |day| {
        generate_day(profile, cfg, &model, day, horizon_s)
    });
    let mut entries = Vec::new();
    for day in per_day {
        entries.extend(day);
    }
    entries
}

/// One day's worth of arrivals, on the day's own forked RNG streams.
/// A day is a pure function of `(profile, cfg, day)` — growing the
/// horizon never perturbs earlier days.
fn generate_day(
    profile: &NetProfile,
    cfg: &GeneratorConfig,
    model: &ThroughputModel,
    day: usize,
    horizon_s: f64,
) -> Vec<LogEntry> {
    let mean_gap_s = 3_600.0 / cfg.transfers_per_hour;
    let mut rng = Rng::fork(cfg.seed ^ ARRIVAL_STREAM, day as u64);
    let traffic_seed = Rng::fork(cfg.seed ^ TRAFFIC_STREAM, day as u64).next_u64();
    let mut traffic = TrafficProcess::new(profile, traffic_seed).with_phase(0.0);

    let day_start = day as f64 * 86_400.0;
    let day_end = ((day + 1) as f64 * 86_400.0).min(horizon_s);
    let mut entries = Vec::new();
    let mut t = day_start + rng.exponential(1.0 / mean_gap_s);

    while t < day_end {
        let class = *rng.choice(&FileSizeClass::all());
        let dataset = Dataset::sample(class, &mut rng);
        let params = Params::new(
            *rng.choice(&PARAM_GRID),
            *rng.choice(&PARAM_GRID),
            *rng.choice(&PP_GRID),
        );
        let load = traffic.at(t);
        let th = model.sample(params, &dataset, &load, &mut rng);
        entries.push(LogEntry {
            timestamp_s: t,
            network: profile.name.to_string(),
            rtt_s: profile.rtt_s,
            bandwidth_mbps: profile.bandwidth_mbps,
            avg_file_mb: dataset.avg_file_mb,
            n_files: dataset.n_files,
            params,
            throughput_mbps: th,
            true_load: load.intensity,
        });
        t += rng.exponential(1.0 / mean_gap_s);
    }
    entries
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_cfg() -> GeneratorConfig {
        GeneratorConfig {
            days: 7.0,
            transfers_per_hour: 8.0,
            seed: 11,
        }
    }

    #[test]
    fn volume_matches_rate() {
        let logs = generate_history(&NetProfile::xsede(), &quick_cfg());
        let expected = 7.0 * 24.0 * 8.0;
        assert!(
            (logs.len() as f64 - expected).abs() < expected * 0.2,
            "{} vs {expected}",
            logs.len()
        );
    }

    #[test]
    fn timestamps_sorted_within_horizon() {
        let logs = generate_history(&NetProfile::xsede(), &quick_cfg());
        for w in logs.windows(2) {
            assert!(w[1].timestamp_s > w[0].timestamp_s);
        }
        assert!(logs.last().unwrap().timestamp_s < 7.0 * 86_400.0);
    }

    #[test]
    fn covers_classes_and_params() {
        let logs = generate_history(&NetProfile::xsede(), &quick_cfg());
        for class in FileSizeClass::all() {
            assert!(
                logs.iter()
                    .any(|e| FileSizeClass::classify(e.avg_file_mb) == class),
                "missing class {class:?}"
            );
        }
        for &cc in &PARAM_GRID {
            assert!(logs.iter().any(|e| e.params.cc == cc), "missing cc={cc}");
        }
    }

    #[test]
    fn throughputs_positive_and_bounded() {
        let p = NetProfile::xsede();
        let logs = generate_history(&p, &quick_cfg());
        for e in &logs {
            assert!(e.throughput_mbps > 0.0);
            // noise can push a sample slightly above the deterministic cap
            assert!(e.throughput_mbps < p.bandwidth_mbps * 1.3);
        }
    }

    #[test]
    fn deterministic_under_seed() {
        let a = generate_history(&NetProfile::didclab(), &quick_cfg());
        let b = generate_history(&NetProfile::didclab(), &quick_cfg());
        assert_eq!(a, b);
    }

    #[test]
    fn day_prefix_is_stable_as_horizon_grows() {
        // per-day forking makes each day a pure function of (cfg, day):
        // a longer horizon appends days without perturbing earlier ones
        let p = NetProfile::xsede();
        let short = generate_history(
            &p,
            &GeneratorConfig {
                days: 2.0,
                ..quick_cfg()
            },
        );
        let long = generate_history(
            &p,
            &GeneratorConfig {
                days: 5.0,
                ..quick_cfg()
            },
        );
        assert!(long.len() > short.len());
        assert_eq!(&long[..short.len()], &short[..]);
    }

    #[test]
    fn fractional_horizon_truncates_last_day() {
        let p = NetProfile::xsede();
        let cfg = GeneratorConfig {
            days: 1.5,
            ..quick_cfg()
        };
        let logs = generate_history(&p, &cfg);
        assert!(!logs.is_empty());
        for e in &logs {
            assert!(e.timestamp_s < 1.5 * 86_400.0);
        }
        // the first full day is untouched by the truncation
        let full = generate_history(
            &p,
            &GeneratorConfig {
                days: 1.0,
                ..quick_cfg()
            },
        );
        assert_eq!(&logs[..full.len()], &full[..]);
    }

    #[test]
    fn empty_horizon_yields_no_entries() {
        let cfg = GeneratorConfig {
            days: 0.0,
            ..quick_cfg()
        };
        assert!(generate_history(&NetProfile::xsede(), &cfg).is_empty());
    }

    #[test]
    fn load_intensity_correlates_with_throughput() {
        // same params + dataset class under heavier load => lower median
        let logs = generate_history(&NetProfile::xsede(), &quick_cfg());
        let (mut light, mut heavy) = (Vec::new(), Vec::new());
        for e in &logs {
            if e.avg_file_mb > 256.0 && e.params.total_streams() >= 16 {
                if e.true_load < 0.25 {
                    light.push(e.throughput_mbps);
                } else if e.true_load > 0.5 {
                    heavy.push(e.throughput_mbps);
                }
            }
        }
        if light.len() > 5 && heavy.len() > 5 {
            let ml = crate::util::stats::median(&light);
            let mh = crate::util::stats::median(&heavy);
            assert!(mh < ml, "heavy={mh} light={ml}");
        }
    }
}
