//! Historical transfer logs — the input to the offline phase.
//!
//! The paper mines *six weeks of GridFTP logs* (§5).  We have no access
//! to those, so [`generator`] replays thousands of randomized transfers
//! through the simulator under the diurnal background-traffic process
//! and records GridFTP-style entries ([`schema::LogEntry`]).  [`store`]
//! persists logs and offline results as JSON (append-friendly, matching
//! the paper's "additive" offline analysis).

pub mod generator;
pub mod schema;
pub mod store;

pub use generator::{generate_history, GeneratorConfig};
pub use schema::LogEntry;
pub use store::LogStore;
