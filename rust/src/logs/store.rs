//! Persistent log store: JSON-lines on disk, append-friendly so the
//! offline analysis stays *additive* ("when new logs are generated ...
//! we do not need to ... perform analysis on the entire log from
//! scratch", §4).

use crate::logs::schema::LogEntry;
use crate::util::json::Value;
use crate::util::err::{Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

/// A file-backed, append-only collection of log entries.
#[derive(Debug)]
pub struct LogStore {
    path: PathBuf,
    entries: Vec<LogEntry>,
}

impl LogStore {
    /// Open (or create) a store at `path`, loading existing entries.
    pub fn open(path: impl AsRef<Path>) -> Result<LogStore> {
        let path = path.as_ref().to_path_buf();
        let mut entries = Vec::new();
        if path.exists() {
            let text = std::fs::read_to_string(&path)
                .with_context(|| format!("reading log store {}", path.display()))?;
            for (i, line) in text.lines().enumerate() {
                if line.trim().is_empty() {
                    continue;
                }
                let v = Value::parse(line)
                    .with_context(|| format!("log store line {}", i + 1))?;
                let e = LogEntry::from_json(&v)
                    .with_context(|| format!("malformed log entry at line {}", i + 1))?;
                entries.push(e);
            }
        }
        Ok(LogStore { path, entries })
    }

    /// An in-memory store (tests, ephemeral experiments).
    pub fn in_memory() -> LogStore {
        LogStore {
            path: PathBuf::new(),
            entries: Vec::new(),
        }
    }

    pub fn entries(&self) -> &[LogEntry] {
        &self.entries
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Append entries in memory and (if file-backed) on disk.
    pub fn append(&mut self, new: &[LogEntry]) -> Result<()> {
        if !self.path.as_os_str().is_empty() {
            let mut f = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(&self.path)
                .with_context(|| format!("opening {}", self.path.display()))?;
            for e in new {
                writeln!(f, "{}", e.to_json())?;
            }
        }
        self.entries.extend_from_slice(new);
        Ok(())
    }

    /// Entries for one network, optionally bounded to a time window.
    pub fn for_network(&self, network: &str, window: Option<(f64, f64)>) -> Vec<&LogEntry> {
        self.entries
            .iter()
            .filter(|e| e.network == network)
            .filter(|e| match window {
                Some((lo, hi)) => e.timestamp_s >= lo && e.timestamp_s < hi,
                None => true,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::Params;

    fn entry(t: f64, net: &str) -> LogEntry {
        LogEntry {
            timestamp_s: t,
            network: net.into(),
            rtt_s: 0.04,
            bandwidth_mbps: 10_000.0,
            avg_file_mb: 64.0,
            n_files: 100,
            params: Params::new(2, 2, 2),
            throughput_mbps: 1234.5,
            true_load: 0.3,
        }
    }

    #[test]
    fn roundtrip_through_disk() {
        let dir = std::env::temp_dir().join(format!("twophase-store-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("logs.jsonl");
        let _ = std::fs::remove_file(&path);

        let mut s = LogStore::open(&path).unwrap();
        assert!(s.is_empty());
        s.append(&[entry(1.0, "xsede"), entry(2.0, "didclab")]).unwrap();

        // appending in a second session preserves earlier entries
        let mut s2 = LogStore::open(&path).unwrap();
        assert_eq!(s2.len(), 2);
        s2.append(&[entry(3.0, "xsede")]).unwrap();

        let s3 = LogStore::open(&path).unwrap();
        assert_eq!(s3.len(), 3);
        assert_eq!(s3.entries()[0], entry(1.0, "xsede"));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn filters_by_network_and_window() {
        let mut s = LogStore::in_memory();
        s.append(&[entry(1.0, "a"), entry(5.0, "a"), entry(9.0, "b")])
            .unwrap();
        assert_eq!(s.for_network("a", None).len(), 2);
        assert_eq!(s.for_network("a", Some((0.0, 2.0))).len(), 1);
        assert_eq!(s.for_network("b", Some((0.0, 2.0))).len(), 0);
    }

    #[test]
    fn corrupted_file_is_an_error() {
        let dir = std::env::temp_dir().join(format!("twophase-corrupt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.jsonl");
        std::fs::write(&path, "{not json\n").unwrap();
        assert!(LogStore::open(&path).is_err());
        std::fs::remove_file(&path).unwrap();
    }
}
