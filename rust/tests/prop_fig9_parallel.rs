//! Thread-invariance of the fig9 multi-user grid fan-out: the full
//! experiment result (every per-tick series point folded into
//! `Fig9Result::digest`) must be bit-identical for `PALLAS_THREADS`
//! ∈ {1, 2, 8}.  Kept as the single test in this binary because it
//! mutates the process-global `PALLAS_THREADS` (and pins
//! `TWOPHASE_DAYS` before anything touches the shared context).

#[test]
fn fig9_digest_is_thread_invariant() {
    // small corpus: the one-time ctx() build is not what's under test
    std::env::set_var("TWOPHASE_DAYS", "3");
    let orig = std::env::var("PALLAS_THREADS").ok();

    let mut digests: Vec<(&str, u64)> = Vec::new();
    for threads in ["1", "2", "8"] {
        std::env::set_var("PALLAS_THREADS", threads);
        let res = twophase::experiments::fig9::run();
        assert!(!res.rows.is_empty(), "paper grid evaluated no cells");
        digests.push((threads, res.digest()));
    }
    match orig {
        Some(v) => std::env::set_var("PALLAS_THREADS", v),
        None => std::env::remove_var("PALLAS_THREADS"),
    }

    let (_, d0) = digests[0];
    for &(threads, d) in &digests[1..] {
        assert_eq!(d, d0, "fig9 digest diverged at {threads} threads");
    }
}
