//! Property: the parallel offline pipeline is bit-identical to serial.
//!
//! The test mutates `PALLAS_THREADS`, a process-global, so everything
//! lives in one `#[test]` — cargo gives each integration-test binary
//! its own process, and a single test function means no sibling thread
//! can race the env var.

use twophase::logs::generator::{generate_history, GeneratorConfig};
use twophase::offline::pipeline::{KnowledgeBase, OfflineConfig};
use twophase::sim::profile::NetProfile;
use twophase::util::par;

#[test]
fn pipeline_output_is_bit_identical_across_thread_counts() {
    // full offline discovery: clustering + surface fits, digested over
    // every label, centroid, coefficient and optimum (order-sensitive)
    for seed in [11u64, 42, 0xB16_DA7A] {
        let logs = generate_history(
            &NetProfile::xsede(),
            &GeneratorConfig {
                days: 3.0,
                transfers_per_hour: 6.0,
                seed,
            },
        );
        let mut digests = Vec::new();
        for threads in ["1", "2", "8"] {
            std::env::set_var("PALLAS_THREADS", threads);
            assert_eq!(par::max_threads(), threads.parse::<usize>().unwrap());
            let kb = KnowledgeBase::build_native(logs.clone(), OfflineConfig::default());
            digests.push((threads, kb.digest()));
        }
        let (_, serial_digest) = digests[0];
        for &(threads, digest) in &digests[1..] {
            assert_eq!(
                digest, serial_digest,
                "seed {seed}: {threads}-thread build diverged from serial"
            );
        }
    }

    // the pool primitive itself: results keyed by index, so the f64
    // bit patterns cannot depend on scheduling
    let xs: Vec<f64> = (0..1_000).map(|i| (i as f64).sin() * 1e6).collect();
    std::env::set_var("PALLAS_THREADS", "1");
    let serial: Vec<u64> = par::par_map(&xs, |i, &x| (x * (i as f64 + 0.5)).to_bits());
    for threads in ["2", "8"] {
        std::env::set_var("PALLAS_THREADS", threads);
        let par: Vec<u64> = par::par_map(&xs, |i, &x| (x * (i as f64 + 0.5)).to_bits());
        assert_eq!(par, serial, "{threads}-thread par_map diverged");
    }
    std::env::remove_var("PALLAS_THREADS");
}
