//! Integration: the three-layer AOT path.  When `make artifacts` has
//! run, the PJRT backends must agree with the native math on real
//! offline workloads; tests skip (never fail) from a clean checkout.

use twophase::logs::generator::{generate_history, GeneratorConfig};
use twophase::offline::kmeans::NativeKmeans;
use twophase::offline::pipeline::{KnowledgeBase, OfflineConfig};
use twophase::offline::surface::NativeSurfaceBackend;
use twophase::runtime::accel::{PjrtKmeans, PjrtSurfaceBackend};
use twophase::runtime::engine::Engine;
use twophase::sim::profile::NetProfile;

fn logs() -> Vec<twophase::logs::schema::LogEntry> {
    generate_history(
        &NetProfile::xsede(),
        &GeneratorConfig {
            days: 8.0,
            transfers_per_hour: 8.0,
            seed: 77,
        },
    )
}

#[test]
fn pjrt_knowledge_base_matches_native_structure() {
    let Some(engine) = Engine::try_default() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let corpus = logs();
    let native = KnowledgeBase::build(
        corpus.clone(),
        OfflineConfig::default(),
        &NativeSurfaceBackend,
        &NativeKmeans,
    );
    let backend = PjrtSurfaceBackend::new(engine);
    let pjrt = KnowledgeBase::build(
        corpus,
        OfflineConfig::default(),
        &backend,
        &NativeKmeans,
    );
    assert_eq!(native.n_surfaces(), pjrt.n_surfaces());
    assert_eq!(native.sets.len(), pjrt.sets.len());
    // bucket optima agree closely (f32 artifacts vs f64 native)
    for (a, b) in native.sets.iter().zip(&pjrt.sets) {
        assert_eq!(a.buckets.len(), b.buckets.len());
        for (ba, bb) in a.buckets.iter().zip(&b.buckets) {
            let rel = (ba.optimal_th - bb.optimal_th).abs() / ba.optimal_th.max(1.0);
            assert!(
                rel < 5e-3,
                "bucket optimum drifted: {} vs {}",
                ba.optimal_th,
                bb.optimal_th
            );
        }
    }
}

#[test]
fn pjrt_kmeans_clusters_like_native() {
    let Some(engine) = Engine::try_default() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let corpus = logs();
    let refs: Vec<&twophase::logs::schema::LogEntry> = corpus.iter().collect();
    let native = twophase::offline::clustering::cluster_logs(&refs, 4, 3, &NativeKmeans);
    let accel = twophase::offline::clustering::cluster_logs(
        &refs,
        4,
        3,
        &PjrtKmeans::new(engine),
    );
    // same seeding + identical assignment steps -> identical result
    assert_eq!(native.k, accel.k);
    assert_eq!(native.labels, accel.labels);
}

#[test]
fn engine_surface_pipeline_is_deterministic() {
    let Some(engine) = Engine::try_default() else {
        eprintln!("skipping: artifacts not built");
        return;
    };
    let m = &engine.manifest;
    let (s, gp, gc) = (
        m.konst("S").unwrap(),
        m.konst("GP").unwrap(),
        m.konst("GC").unwrap(),
    );
    let xs: Vec<f32> = (0..gp).map(|i| (i + 1) as f32).collect();
    let ys: Vec<f32> = (0..gc).map(|i| (i + 1) as f32).collect();
    let values: Vec<f32> = (0..s * gp * gc).map(|i| ((i * 31) % 211) as f32).collect();
    let a = engine.surface_pipeline(&xs, &ys, &values).unwrap();
    let b = engine.surface_pipeline(&xs, &ys, &values).unwrap();
    assert_eq!(a.coeffs, b.coeffs);
    assert_eq!(a.maxv, b.maxv);
}
