//! Thread-invariance of the parallelized K-means++ D² refresh: the
//! seeding and the full Lloyd run must produce bit-identical centroids
//! for any worker count.  Kept as the single test in this binary
//! because it mutates the process-global `PALLAS_THREADS`.

use twophase::offline::features::N_FEATURES;
use twophase::offline::kmeans::{kmeans, kmeanspp_init, NativeKmeans};
use twophase::util::rng::Rng;

/// FNV-1a over the exact bit patterns of a centroid set.
fn digest(centroids: &[[f64; N_FEATURES]]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for c in centroids {
        for v in c {
            for b in v.to_bits().to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
        }
    }
    h
}

fn blobs(n: usize, seed: u64) -> Vec<[f64; N_FEATURES]> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let center = (i % 5) as f64 * 10.0;
            let mut p = [0.0; N_FEATURES];
            for v in &mut p {
                *v = center + rng.normal();
            }
            p
        })
        .collect()
}

#[test]
fn kmeanspp_digest_is_thread_invariant() {
    // > KPP_CHUNK points so the refresh actually spans several chunks
    let points = blobs(3000, 0x5eed);
    let orig = std::env::var("PALLAS_THREADS").ok();

    let mut digests: Vec<(String, u64, u64)> = Vec::new();
    for threads in ["1", "2", "8"] {
        std::env::set_var("PALLAS_THREADS", threads);
        let init = kmeanspp_init(&points, 5, &mut Rng::new(42));
        let full = kmeans(&points, 5, &mut Rng::new(42), &NativeKmeans);
        digests.push((threads.to_string(), digest(&init), digest(&full.centroids)));
    }
    match orig {
        Some(v) => std::env::set_var("PALLAS_THREADS", v),
        None => std::env::remove_var("PALLAS_THREADS"),
    }

    let (_, init0, full0) = digests[0].clone();
    for (threads, init, full) in &digests[1..] {
        assert_eq!(
            *init, init0,
            "kmeanspp_init digest diverged at {threads} threads"
        );
        assert_eq!(
            *full, full0,
            "kmeans centroid digest diverged at {threads} threads"
        );
    }
}
