//! Thread-invariance of the per-day `generate_history` fan-out: the
//! full log corpus (every field of every entry, bit patterns included)
//! must be identical for `PALLAS_THREADS` ∈ {1, 2, 8}.  Kept as the
//! single test in this binary because it mutates the process-global
//! `PALLAS_THREADS`.

use twophase::logs::generator::{generate_history, GeneratorConfig};
use twophase::logs::schema::LogEntry;
use twophase::sim::profile::NetProfile;

/// FNV-1a over the exact bit patterns of a log corpus.
fn digest(entries: &[LogEntry]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    let mut mix = |bytes: &[u8]| {
        for &b in bytes {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    mix(&(entries.len() as u64).to_le_bytes());
    for e in entries {
        mix(&e.timestamp_s.to_bits().to_le_bytes());
        mix(e.network.as_bytes());
        mix(&e.rtt_s.to_bits().to_le_bytes());
        mix(&e.bandwidth_mbps.to_bits().to_le_bytes());
        mix(&e.avg_file_mb.to_bits().to_le_bytes());
        mix(&e.n_files.to_le_bytes());
        mix(&e.params.cc.to_le_bytes());
        mix(&e.params.p.to_le_bytes());
        mix(&e.params.pp.to_le_bytes());
        mix(&e.throughput_mbps.to_bits().to_le_bytes());
        mix(&e.true_load.to_bits().to_le_bytes());
    }
    h
}

#[test]
fn history_digest_is_thread_invariant() {
    let orig = std::env::var("PALLAS_THREADS").ok();
    // seeds × profiles × horizons, fractional horizon included so the
    // truncated-last-day path is covered too
    let cases: Vec<(NetProfile, GeneratorConfig)> = [11u64, 42, 0xB16_DA7A]
        .iter()
        .flat_map(|&seed| {
            [NetProfile::xsede(), NetProfile::didclab()]
                .into_iter()
                .flat_map(move |p| {
                    [2.0f64, 2.5].into_iter().map(move |days| {
                        (
                            p.clone(),
                            GeneratorConfig {
                                days,
                                transfers_per_hour: 8.0,
                                seed,
                            },
                        )
                    })
                })
        })
        .collect();

    let mut digests: Vec<(&str, Vec<u64>)> = Vec::new();
    for threads in ["1", "2", "8"] {
        std::env::set_var("PALLAS_THREADS", threads);
        let ds: Vec<u64> = cases
            .iter()
            .map(|(p, cfg)| {
                let entries = generate_history(p, cfg);
                assert!(!entries.is_empty());
                digest(&entries)
            })
            .collect();
        digests.push((threads, ds));
    }
    match orig {
        Some(v) => std::env::set_var("PALLAS_THREADS", v),
        None => std::env::remove_var("PALLAS_THREADS"),
    }

    let (_, d0) = digests[0].clone();
    for (threads, ds) in &digests[1..] {
        assert_eq!(
            *ds, d0,
            "generate_history digest diverged at {threads} threads"
        );
    }
}
