//! Property-based invariants across the stack, run through the in-tree
//! `util::prop` framework (proptest is unavailable offline).

use twophase::offline::spline::BicubicSurface;
use twophase::offline::surface::SurfaceGrid;
use twophase::sim::dataset::Dataset;
use twophase::sim::link::{share_bottleneck, LinkDemand};
use twophase::sim::profile::NetProfile;
use twophase::sim::traffic::TrafficProcess;
use twophase::sim::transfer::ThroughputModel;
use twophase::util::prop::run;
use twophase::util::stats;
use twophase::Params;

#[test]
fn prop_throughput_within_physical_bounds() {
    run("throughput within bounds", 150, |g| {
        let profiles = NetProfile::all();
        let p = profiles[g.usize_in(0..=3)].clone();
        let model = ThroughputModel::new(p.clone());
        let load = TrafficProcess::fixed(&p, g.f64_in(0.0..1.0));
        let params = Params::new(g.u32_in(1..=32), g.u32_in(1..=32), g.u32_in(1..=32));
        let dataset = Dataset::new(g.usize_in(1..=50_000) as u64, g.f64_in(0.1..4096.0));
        let th = model.steady(params, &dataset, &load);
        assert!(th >= 0.0, "negative throughput");
        assert!(th <= p.bandwidth_mbps + 1e-9, "exceeds link");
        assert!(th <= p.disk_mbps + 1e-9, "exceeds disk");
        assert!(th.is_finite());
    });
}

#[test]
fn prop_throughput_monotone_in_background_load() {
    run("throughput non-increasing in load", 60, |g| {
        let p = NetProfile::xsede();
        let model = ThroughputModel::new(p.clone());
        let params = Params::new(g.u32_in(1..=16), g.u32_in(1..=8), g.u32_in(1..=32));
        let dataset = Dataset::new(256, g.f64_in(1.0..1024.0));
        let mut prev = f64::INFINITY;
        for step in 0..6 {
            let load = TrafficProcess::fixed(&p, step as f64 / 5.0);
            let th = model.steady(params, &dataset, &load);
            assert!(
                th <= prev * 1.0001,
                "throughput rose with load at step {step}: {th} > {prev}"
            );
            prev = th;
        }
    });
}

#[test]
fn prop_spline_interpolates_every_random_grid() {
    run("bicubic interpolation", 60, |g| {
        let gp = g.usize_in(3..=8);
        let gc = g.usize_in(3..=8);
        let xs = g.knots(gp);
        let ys = g.knots(gc);
        let values: Vec<Vec<f64>> = (0..gp)
            .map(|_| (0..gc).map(|_| g.f64_in(-500.0..500.0)).collect())
            .collect();
        let s = BicubicSurface::fit(&xs, &ys, &values);
        for i in 0..gp {
            for j in 0..gc {
                let got = s.eval(xs[i], ys[j]);
                assert!(
                    (got - values[i][j]).abs() < 1e-6,
                    "knot ({i},{j}): {got} vs {}",
                    values[i][j]
                );
            }
        }
    });
}

#[test]
fn prop_surface_grid_fill_is_complete_and_bounded() {
    run("grid fill", 80, |g| {
        let n = g.usize_in(1..=40);
        let grid_vals = [1u32, 2, 4, 6, 8, 12, 16, 32];
        let obs: Vec<(Params, f64)> = (0..n)
            .map(|_| {
                (
                    Params::new(
                        grid_vals[g.usize_in(0..=7)],
                        grid_vals[g.usize_in(0..=7)],
                        4,
                    ),
                    g.f64_in(1.0..1000.0),
                )
            })
            .collect();
        let grid = SurfaceGrid::from_observations(&obs);
        let lo = obs.iter().map(|(_, v)| *v).fold(f64::INFINITY, f64::min);
        let hi = obs.iter().map(|(_, v)| *v).fold(0.0, f64::max);
        for row in &grid.values {
            for &v in row {
                assert!(v.is_finite(), "unfilled cell");
                // neighbor averaging never escapes the observed range
                assert!(v >= lo - 1e-9 && v <= hi + 1e-9, "{v} outside [{lo},{hi}]");
            }
        }
    });
}

#[test]
fn prop_bottleneck_share_conserves_capacity() {
    run("water-fill conservation", 120, |g| {
        let n = g.usize_in(1..=8);
        let cap = g.f64_in(100.0..10_000.0);
        let demands: Vec<LinkDemand> = (0..n)
            .map(|_| LinkDemand {
                streams: g.f64_in(1.0..64.0),
                demand_mbps: g.f64_in(1.0..20_000.0),
            })
            .collect();
        let bg = g.f64_in(0.0..64.0);
        let alloc = share_bottleneck(cap, &demands, bg);
        let total: f64 = alloc.iter().sum();
        assert!(total <= cap + 1e-6, "oversubscribed: {total} > {cap}");
        for (a, d) in alloc.iter().zip(&demands) {
            assert!(*a >= -1e-9 && *a <= d.demand_mbps + 1e-6);
        }
    });
}

#[test]
fn prop_four_equal_users_share_fairly() {
    run("fair share under symmetry", 20, |g| {
        use twophase::sim::multiuser::{MultiUserSim, UserCtx, UserPolicy};
        let params = Params::new(g.u32_in(2..=16), g.u32_in(1..=8), 8);
        struct Fixed(Params);
        impl UserPolicy for Fixed {
            fn decide(&mut self, _c: &UserCtx) -> Params {
                self.0
            }
        }
        let mut sim = MultiUserSim::new(NetProfile::chameleon(), g.rng().next_u64());
        let mut pols: Vec<Box<dyn UserPolicy>> =
            (0..4).map(|_| Box::new(Fixed(params)) as Box<dyn UserPolicy>).collect();
        let ds = vec![Dataset::new(256, 512.0); 4];
        let out = sim.run(&mut pols, &ds, 120.0);
        let means: Vec<f64> = out.iter().map(|u| u.mean_throughput_mbps).collect();
        let jain = stats::jain_index(&means);
        assert!(jain > 0.95, "jain {jain} for identical users: {means:?}");
    });
}

#[test]
fn prop_log_entries_roundtrip_json() {
    run("log JSON roundtrip", 100, |g| {
        let e = twophase::logs::schema::LogEntry {
            timestamp_s: g.f64_in(0.0..4e6),
            network: "xsede".into(),
            rtt_s: g.f64_in(1e-4..0.2),
            bandwidth_mbps: g.f64_in(100.0..1e5),
            avg_file_mb: g.f64_in(0.1..4096.0),
            n_files: g.usize_in(1..=100_000) as u64,
            params: Params::new(g.u32_in(1..=32), g.u32_in(1..=32), g.u32_in(1..=32)),
            throughput_mbps: g.f64_in(0.1..1e4),
            true_load: g.f64_in(0.0..1.0),
        };
        let back = twophase::logs::schema::LogEntry::from_json(&e.to_json()).unwrap();
        assert_eq!(e, back);
    });
}

#[test]
fn prop_rng_fork_is_deterministic_distinct_and_order_free() {
    use twophase::util::rng::Rng;
    run("rng fork seeding rule", 100, |g| {
        let seed = g.rng().next_u64();
        let n = g.usize_in(2..=16);

        // deterministic: the same (seed, idx) always yields the same
        // stream — a fork is a pure function, independent of any
        // generator state
        for idx in 0..n as u64 {
            let mut a = Rng::fork(seed, idx);
            let mut b = Rng::fork(seed, idx);
            for _ in 0..8 {
                assert_eq!(a.next_u64(), b.next_u64());
            }
        }

        // pairwise distinct: different indices open different streams
        let firsts: Vec<u64> = (0..n as u64)
            .map(|idx| Rng::fork(seed, idx).next_u64())
            .collect();
        for i in 0..n {
            for j in (i + 1)..n {
                assert_ne!(
                    firsts[i], firsts[j],
                    "fork({seed:#x}, {i}) collides with fork({seed:#x}, {j})"
                );
            }
        }

        // fork-order independent: forking in reverse (as a racing pool
        // worker might) changes nothing
        let reversed: Vec<u64> = (0..n as u64)
            .rev()
            .map(|idx| Rng::fork(seed, idx).next_u64())
            .collect();
        for (i, &v) in reversed.iter().rev().enumerate() {
            assert_eq!(firsts[i], v, "fork order leaked into stream {i}");
        }
    });
}

#[test]
fn prop_param_change_penalty_nonnegative_and_zero_on_identity() {
    run("penalty sanity", 100, |g| {
        let p = NetProfile::xsede();
        let model = ThroughputModel::new(p);
        let a = Params::new(g.u32_in(1..=32), g.u32_in(1..=32), g.u32_in(1..=32));
        let b = Params::new(g.u32_in(1..=32), g.u32_in(1..=32), g.u32_in(1..=32));
        assert_eq!(model.param_change_penalty_s(a, a), 0.0);
        let pen = model.param_change_penalty_s(a, b);
        assert!(pen >= 0.0 && pen < 60.0, "penalty {pen}");
    });
}
