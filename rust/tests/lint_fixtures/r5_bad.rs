// R5 fixture: panicking escape hatches in library code must be flagged.
fn risky(v: Option<u32>, r: Result<u32, String>) -> u32 {
    let a = v.unwrap();
    let b = r.expect("always ok");
    if a + b > 100 {
        panic!("overflow");
    }
    todo!()
}
