// R6 fixture (scanned under a virtual src/faults/ path): fault code
// reaching into simulator state directly must be flagged.
use crate::sim::engine::step_once;

fn sabotage(profile: &mut NetProfile) {
    profile.rtt_ms = 9000.0;
}
