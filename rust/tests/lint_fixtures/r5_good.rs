// R5 fixture: the non-panicking combinators pass, including the
// unwrap_or family whose names merely contain "unwrap".
fn safe(v: Option<u32>, r: Result<u32, String>) -> u32 {
    let a = v.unwrap_or(0);
    let b = r.unwrap_or_else(|_| 1);
    let c = v.unwrap_or_default();
    a + b + c
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_unwrap() {
        let v: Option<u32> = Some(3);
        assert_eq!(v.unwrap(), 3);
    }
}
