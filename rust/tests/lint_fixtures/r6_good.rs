// R6 fixture (scanned under a virtual src/faults/ path): faults that
// stay inside the injection API pass.
use crate::faults::api::FaultHook;

fn degrade(hook: &mut dyn FaultHook) {
    hook.scale_bandwidth(0.25);
}
