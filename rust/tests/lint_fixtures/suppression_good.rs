// Suppression fixture: a directive with a rule id and a reason silences
// the violation on its own line and the next one.
fn checked(v: Option<u32>) -> u32 {
    // pallas-lint: allow(panic-in-lib, fixture demonstrating a justified escape hatch)
    v.unwrap()
}

fn inline(v: Option<u32>) -> u32 {
    v.unwrap() // pallas-lint: allow(panic-in-lib, same-line form also counts)
}
