// R1 fixture: ordered containers pass, and mentions inside strings or
// comments (HashMap does not count here) are invisible to the lexer.
use std::collections::BTreeMap;
use std::collections::BTreeSet;

fn build() -> BTreeMap<String, u32> {
    let mut m = BTreeMap::new();
    m.insert("HashMap".to_string(), 1); // the string literal is stripped
    let _s: BTreeSet<u32> = BTreeSet::new();
    m
}
