// R3 fixture: timing through the sanctioned wrapper passes.
use crate::util::timer::time_once;

fn measure() -> f64 {
    let (_, t) = time_once(|| 1 + 1);
    t.as_secs_f64()
}
