// R2 fixture: ad-hoc threading outside util::par must be flagged.
fn fan_out() {
    let h = std::thread::spawn(|| 42);
    let _ = h.join();
    std::thread::scope(|s| {
        s.spawn(|| ());
    });
}
