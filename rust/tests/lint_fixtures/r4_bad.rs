// R4 fixture: OS-entropy randomness must be flagged.
fn seed_state() -> u64 {
    let mut rng = rand::thread_rng();
    let _hasher = std::collections::hash_map::RandomState::new();
    rng.gen()
}
