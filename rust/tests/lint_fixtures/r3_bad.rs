// R3 fixture: wall-clock reads outside util::timer must be flagged.
use std::time::Instant;

fn measure() -> f64 {
    let t0 = Instant::now();
    let _ = std::time::SystemTime::now();
    t0.elapsed().as_secs_f64()
}
