// R4 fixture: the in-tree seeded generator passes — determinism comes
// from explicit seeds, not from banning randomness altogether.
use crate::util::rng::Rng;

fn draw(seed: u64) -> u64 {
    let mut rng = Rng::new(seed);
    rng.next_u64()
}
