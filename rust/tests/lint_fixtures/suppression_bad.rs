// Suppression fixture: a reasonless directive is itself a violation and
// suppresses nothing; an unknown rule id is also flagged.
fn unjustified(v: Option<u32>) -> u32 {
    // pallas-lint: allow(panic-in-lib)
    v.unwrap()
}

fn misspelled(v: Option<u32>) -> u32 {
    // pallas-lint: allow(panics-in-lib, the rule id has a typo)
    v.unwrap()
}
