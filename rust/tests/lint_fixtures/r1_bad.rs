// R1 fixture: non-deterministic hash containers must be flagged.
use std::collections::HashMap;
use std::collections::HashSet;

fn build() -> HashMap<String, u32> {
    let mut m = HashMap::new();
    m.insert("a".to_string(), 1);
    let _s: HashSet<u32> = HashSet::new();
    m
}
