// R2 fixture: going through the deterministic pool passes.
use crate::util::par;

fn fan_out(items: &[u32]) -> Vec<u32> {
    par::par_map(items, |_, &x| x * 2)
}
