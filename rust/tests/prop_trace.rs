//! Property: the trace a seeded workload leaves behind is byte-
//! identical for any `PALLAS_THREADS` setting.
//!
//! This is the `util::trace` analogue of `prop_parallel.rs`: a seeded
//! exp_robustness-style cell (faulted ASM transfers, tuning cache on)
//! fans out over `util::par`, and the JSONL export — records, sequence
//! numbers, metric folds, everything — must not depend on how many
//! workers drained the queue.  The test mutates `PALLAS_THREADS`, a
//! process-global, so everything lives in one `#[test]` (cargo gives
//! each integration-test binary its own process).

use std::sync::Arc;

use twophase::baselines::ann_ot::AnnOtModel;
use twophase::baselines::api::OptimizerKind;
use twophase::baselines::static_ann::StaticAnnModel;
use twophase::coordinator::orchestrator::{Orchestrator, OrchestratorConfig, TransferRequest};
use twophase::faults::{FaultPlan, FaultPlanConfig};
use twophase::logs::generator::{generate_history, GeneratorConfig};
use twophase::offline::pipeline::{KnowledgeBase, OfflineConfig};
use twophase::sim::dataset::Dataset;
use twophase::sim::profile::NetProfile;
use twophase::util::trace::{schema_of_jsonl, Tracer};
use twophase::util::{json, par};

#[test]
fn trace_export_is_bit_identical_across_thread_counts() {
    let profile = NetProfile::xsede();
    let logs = generate_history(
        &profile,
        &GeneratorConfig {
            days: 3.0,
            transfers_per_hour: 6.0,
            seed: 42,
        },
    );
    let kb = Arc::new(KnowledgeBase::build_native(
        logs.clone(),
        OfflineConfig::default(),
    ));
    let sp = Arc::new(StaticAnnModel::train(&logs, 32, 0xE1));
    let annot = Arc::new(AnnOtModel::train(&logs, 32, 0xE2));

    let mut exports: Vec<(&str, String)> = Vec::new();
    for threads in ["1", "2", "8"] {
        std::env::set_var("PALLAS_THREADS", threads);
        assert_eq!(par::max_threads(), threads.parse::<usize>().unwrap());
        let orch = Orchestrator::new(
            Arc::clone(&kb),
            Arc::clone(&sp),
            Arc::clone(&annot),
            OrchestratorConfig {
                cache_capacity: 8,
                ..OrchestratorConfig::default()
            },
        )
        .expect("3-day corpus yields a non-empty knowledge base");
        let tracer = Arc::new(Tracer::new());
        orch.set_tracer(Some(Arc::clone(&tracer)));

        // one seeded exp_robustness-style cell: faulted ASM transfers
        // with distinct fingerprints (cache verdicts must not depend on
        // worker interleaving), fanned out over the pool under test
        let requests: Vec<TransferRequest> = (0..4u64)
            .map(|i| TransferRequest {
                id: i + 1,
                profile: profile.clone(),
                dataset: Dataset::new(64 << i, 128.0),
                model: OptimizerKind::Asm,
                seed: 0x5EED ^ (i << 16),
                phase_s: 7_200.0,
            })
            .collect();
        let reports = par::par_map(&requests, |i, req| {
            let plan = FaultPlan::generate(
                &profile,
                &FaultPlanConfig {
                    events_per_hour: 60.0,
                    ..FaultPlanConfig::with_intensity(0.6)
                },
                0xFA117 ^ ((i as u64) << 8),
            );
            orch.execute_with_faults(req, Some(plan))
        });
        assert_eq!(reports.len(), 4);
        orch.set_tracer(None);
        exports.push((threads, tracer.export_string()));
    }
    std::env::remove_var("PALLAS_THREADS");

    let (_, serial) = &exports[0];
    assert!(!serial.is_empty());
    for (threads, export) in &exports[1..] {
        assert_eq!(
            export, serial,
            "{threads}-thread trace diverged from serial (byte comparison)"
        );
    }

    // every line is valid JSON with a kind, and the schema matches the
    // golden file the CI smoke checks against
    let mut n_lines = 0usize;
    for line in serial.lines() {
        let v = json::Value::parse(line).expect("trace line parses as JSON");
        assert!(v.get("kind").as_str().is_some(), "line missing kind: {line}");
        n_lines += 1;
    }
    assert!(n_lines > 10, "expected a substantial trace, got {n_lines} lines");
    let golden = std::fs::read_to_string("../scripts/trace-schema.golden")
        .expect("golden schema is checked in");
    assert_eq!(
        schema_of_jsonl(serial).expect("schema extraction"),
        golden,
        "trace schema drifted from scripts/trace-schema.golden"
    );
}
