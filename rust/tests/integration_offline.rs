//! Integration: the offline phase recovers the simulator's ground
//! truth from generated history — clustering separates contexts, load
//! buckets order correctly, surface optima land near the true optima.

use twophase::logs::generator::{generate_history, GeneratorConfig};
use twophase::offline::pipeline::{KnowledgeBase, OfflineConfig};
use twophase::sim::dataset::{Dataset, FileSizeClass};
use twophase::sim::profile::NetProfile;
use twophase::sim::traffic::TrafficProcess;
use twophase::sim::transfer::ThroughputModel;
use std::sync::OnceLock;

fn kb() -> &'static KnowledgeBase {
    static KB: OnceLock<KnowledgeBase> = OnceLock::new();
    KB.get_or_init(|| {
        let mut logs = Vec::new();
        for p in [NetProfile::xsede(), NetProfile::didclab_xsede()] {
            logs.extend(generate_history(
                &p,
                &GeneratorConfig {
                    days: 14.0,
                    transfers_per_hour: 10.0,
                    seed: 99,
                },
            ));
        }
        KnowledgeBase::build_native(logs, OfflineConfig::default())
    })
}

#[test]
fn clusters_and_classes_are_separated() {
    let kb = kb();
    assert!(kb.clustering.k >= 2);
    // every (network, class) query should resolve to a set of the
    // right class
    for p in [NetProfile::xsede(), NetProfile::didclab_xsede()] {
        for (favg, class) in [
            (1.0, FileSizeClass::Small),
            (64.0, FileSizeClass::Medium),
            (1024.0, FileSizeClass::Large),
        ] {
            let set = kb.query(p.rtt_s, p.bandwidth_mbps, favg, 256).unwrap();
            assert_eq!(set.class, class, "{} favg={favg}", p.name);
        }
    }
}

#[test]
fn surface_optimum_is_near_true_optimum() {
    let kb = kb();
    let p = NetProfile::xsede();
    let model = ThroughputModel::new(p.clone());
    let dataset = Dataset::new(64, 512.0);

    let set = kb
        .query(p.rtt_s, p.bandwidth_mbps, dataset.avg_file_mb, dataset.n_files)
        .unwrap();
    // compare each bucket's recommendation against the true optimum at
    // the bucket's true mean load: recommended params must achieve a
    // large fraction of the optimal throughput
    let mut checked = 0;
    for b in &set.buckets {
        let load = TrafficProcess::fixed(&p, b.true_intensity);
        let (_, best) = model.true_optimum(&dataset, &load);
        let achieved = model.steady(b.optimal_params, &dataset, &load);
        if best > 0.0 {
            let frac = achieved / best;
            assert!(
                frac > 0.55,
                "bucket {}: {} achieves only {:.0}% of optimal",
                b.bucket,
                b.optimal_params,
                frac * 100.0
            );
            checked += 1;
        }
    }
    assert!(checked >= 2, "too few buckets to validate");
}

#[test]
fn bucket_peaks_decrease_with_load_overall() {
    let kb = kb();
    let mut ordered = 0usize;
    let mut total = 0usize;
    for set in &kb.sets {
        if set.buckets.len() >= 2 {
            total += 1;
            let first = set.buckets.first().unwrap();
            let last = set.buckets.last().unwrap();
            if last.optimal_th <= first.optimal_th * 1.1 {
                ordered += 1;
            }
        }
    }
    assert!(total > 0);
    assert!(
        ordered * 3 >= total * 2,
        "only {ordered}/{total} sets show load-ordered peaks"
    );
}

#[test]
fn additive_update_improves_or_keeps_coverage() {
    let mut logs = generate_history(
        &NetProfile::xsede(),
        &GeneratorConfig {
            days: 8.0,
            transfers_per_hour: 8.0,
            seed: 5,
        },
    );
    let extra = generate_history(
        &NetProfile::xsede(),
        &GeneratorConfig {
            days: 4.0,
            transfers_per_hour: 8.0,
            seed: 6,
        },
    );
    let mut kb = KnowledgeBase::build_native(logs.clone(), OfflineConfig::default());
    let before = kb.n_surfaces();
    let before_entries = kb.n_entries();
    kb.update(
        extra.clone(),
        &twophase::offline::surface::NativeSurfaceBackend,
    );
    assert_eq!(kb.n_entries(), before_entries + extra.len());
    assert!(
        kb.n_surfaces() + 2 >= before,
        "surfaces dropped: {} -> {}",
        before,
        kb.n_surfaces()
    );
    logs.extend(extra);
}

#[test]
fn sampling_regions_exist_and_are_in_domain() {
    let kb = kb();
    for set in &kb.sets {
        assert!(
            !set.sampling.is_empty(),
            "cluster {} class {:?} has no sampling region",
            set.cluster,
            set.class
        );
        for q in &set.sampling {
            assert!((1..=32).contains(&q.params.cc));
            assert!((1..=32).contains(&q.params.p));
            assert!((1..=32).contains(&q.params.pp));
        }
    }
}
