//! Property tests for the fault-injection subsystem (in-tree
//! [`twophase::util::prop`] framework):
//!
//! * determinism — the same seed always yields the identical
//!   fault-event sequence, on any profile and schedule config;
//! * conservation — under injected faults, no chunk's measured
//!   throughput exceeds the degraded link capacity in force when the
//!   chunk started.

use twophase::faults::{FaultEngine, FaultKind, FaultPlan, FaultPlanConfig};
use twophase::sim::dataset::Dataset;
use twophase::sim::engine::SimEnv;
use twophase::sim::profile::NetProfile;
use twophase::util::prop::run;
use twophase::Params;

#[test]
fn same_seed_gives_identical_fault_sequence() {
    run("same seed => identical fault-event sequence", 100, |g| {
        let profiles = NetProfile::all();
        let profile = &profiles[g.usize_in(0..=profiles.len() - 1)];
        let cfg = FaultPlanConfig {
            horizon_s: g.f64_in(600.0..14_400.0),
            events_per_hour: g.f64_in(1.0..120.0),
            intensity: g.f64_in(0.0..1.0),
            kinds: FaultKind::all().to_vec(),
        };
        let seed = g.usize_in(0..=u32::MAX as usize) as u64;
        let a = FaultPlan::generate(profile, &cfg, seed);
        let b = FaultPlan::generate(profile, &cfg, seed);
        assert_eq!(a, b, "seed {seed:#x} must reproduce its schedule");
        // structural sanity on the generated schedule
        assert!(a
            .events
            .windows(2)
            .all(|w| w[0].t_start_s <= w[1].t_start_s));
        assert!(a.events.iter().all(|e| {
            e.t_start_s >= 0.0 && e.t_start_s < cfg.horizon_s && e.duration_s > 0.0
        }));
    });
}

#[test]
fn delivered_bytes_respect_degraded_capacity() {
    // Stalls are excluded so every chunk starts exactly at the previous
    // sample's t_s (stall dead time would shift the start without a
    // sample recording it); capacity conservation is about the
    // bandwidth-shaping kinds anyway.
    let kinds = vec![
        FaultKind::LinkDegradation,
        FaultKind::LossBurst,
        FaultKind::RttInflation,
        FaultKind::TrafficSurge,
    ];
    run("throughput <= degraded capacity at chunk start", 40, |g| {
        let profiles = NetProfile::all();
        let profile = profiles[g.usize_in(0..=profiles.len() - 1)].clone();
        let cfg = FaultPlanConfig {
            horizon_s: 7_200.0,
            events_per_hour: g.f64_in(20.0..120.0),
            intensity: g.f64_in(0.2..1.0),
            kinds: kinds.clone(),
        };
        let seed = g.usize_in(0..=u32::MAX as usize) as u64;
        let plan = FaultPlan::generate(&profile, &cfg, seed);
        let engine = FaultEngine::new(plan.clone());

        let menu = [
            Params::new(1, 1, 1),
            Params::new(4, 2, 4),
            Params::new(8, 4, 8),
            Params::new(16, 8, 8),
        ];
        let params = menu[g.usize_in(0..=menu.len() - 1)];
        let dataset = Dataset::new(64, g.f64_in(64.0..512.0));
        let chunk_mb = g.f64_in(256.0..2_048.0);

        let mut env = SimEnv::new(profile.clone(), seed ^ 0x51).with_faults(plan);
        let out = env.run_transfer(&dataset, chunk_mb, |_, _| params);

        let mut chunk_start_s = 0.0;
        for s in &out.samples {
            let cap =
                profile.bandwidth_mbps * engine.state_at(chunk_start_s).capacity_factor;
            assert!(
                s.throughput_mbps <= cap * (1.0 + 1e-9),
                "chunk starting at t={chunk_start_s:.1}s on {} delivered \
                 {:.1} Mbps > degraded capacity {cap:.1} Mbps (seed {seed:#x})",
                profile.name,
                s.throughput_mbps,
            );
            chunk_start_s = s.t_s;
        }
    });
}
