//! End-to-end integration: the orchestrator serving the full model
//! matrix, asserting the paper's headline *shapes* (who wins, by
//! roughly what factor) on the shared experiment context.
//!
//! Quick settings so the suite stays single-core friendly.

use twophase::baselines::api::OptimizerKind;
use twophase::coordinator::orchestrator::TransferRequest;
use twophase::experiments::common::{ctx, OFFPEAK_PHASE_S};
use twophase::sim::dataset::Dataset;
use twophase::sim::profile::NetProfile;
use twophase::util::stats;

fn init_quick() {
    // keep the shared context small for CI-style runs
    if std::env::var("TWOPHASE_DAYS").is_err() {
        std::env::set_var("TWOPHASE_DAYS", "7");
    }
}

fn mean_throughput(model: OptimizerKind, dataset: &Dataset, net: &str, reps: u64) -> f64 {
    let c = ctx();
    let ths: Vec<f64> = (0..reps)
        .map(|rep| {
            let req = TransferRequest {
                id: rep,
                profile: NetProfile::by_name(net).unwrap(),
                dataset: dataset.clone(),
                model,
                seed: 0xE2E ^ rep,
                phase_s: OFFPEAK_PHASE_S,
            };
            c.orchestrator.execute(&req).avg_throughput_mbps
        })
        .collect();
    stats::mean(&ths)
}

#[test]
fn asm_beats_default_by_large_factor() {
    init_quick();
    let d = Dataset::new(64, 512.0);
    let asm = mean_throughput(OptimizerKind::Asm, &d, "xsede", 3);
    let noopt = mean_throughput(OptimizerKind::NoOpt, &d, "xsede", 3);
    assert!(
        asm > 3.0 * noopt,
        "ASM {asm:.0} should be >3x NoOpt {noopt:.0} (paper: ~5x)"
    );
}

#[test]
fn asm_beats_globus_static() {
    init_quick();
    let d = Dataset::new(64, 512.0);
    let asm = mean_throughput(OptimizerKind::Asm, &d, "xsede", 3);
    let go = mean_throughput(OptimizerKind::Globus, &d, "xsede", 3);
    assert!(asm > 1.3 * go, "ASM {asm:.0} vs GO {go:.0}");
}

#[test]
fn asm_at_least_matches_harp_on_every_class() {
    init_quick();
    for (files, avg) in [(20_000u64, 1.0), (512, 64.0), (64, 512.0)] {
        let d = Dataset::new(files, avg);
        let asm = mean_throughput(OptimizerKind::Asm, &d, "xsede", 3);
        let harp = mean_throughput(OptimizerKind::Harp, &d, "xsede", 3);
        assert!(
            asm > 0.9 * harp,
            "class avg={avg}: ASM {asm:.0} vs HARP {harp:.0}"
        );
    }
}

#[test]
fn every_model_completes_on_every_network() {
    init_quick();
    let d = Dataset::new(128, 64.0);
    for net in ["xsede", "didclab", "didclab-xsede"] {
        for model in OptimizerKind::all() {
            let th = mean_throughput(model, &d, net, 1);
            assert!(
                th > 0.0,
                "{} on {net} produced no throughput",
                model.label()
            );
        }
    }
}

#[test]
fn asm_sampling_overhead_is_small() {
    init_quick();
    let c = ctx();
    let req = TransferRequest {
        id: 0,
        profile: NetProfile::xsede(),
        dataset: Dataset::new(64, 512.0),
        model: OptimizerKind::Asm,
        seed: 4,
        phase_s: OFFPEAK_PHASE_S,
    };
    let r = c.orchestrator.execute(&req);
    assert!(r.sample_transfers <= 4, "{} samples", r.sample_transfers);
    // total transfer throughput within 30% of the steady phase: the
    // sampling head must not dominate
    assert!(
        r.avg_throughput_mbps > 0.7 * r.steady_throughput_mbps,
        "avg {:.0} vs steady {:.0}",
        r.avg_throughput_mbps,
        r.steady_throughput_mbps
    );
}
