//! Integration tests for `pallas-lint`: every rule against its
//! good/bad fixture pair in `tests/lint_fixtures/`, the suppression
//! semantics, the exemption paths, and a self-run proving the crate's
//! own `src/` tree is clean against the checked-in baseline.

use std::path::Path;

use twophase::analysis::{baseline, scan_source, scan_tree, Violation};

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/lint_fixtures")
        .join(name);
    std::fs::read_to_string(&p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()))
}

/// Scan a fixture under a virtual crate-relative path (exemptions and
/// the R6 scope are keyed on the path, not the file location).
fn scan_fixture(name: &str, virtual_path: &str) -> Vec<Violation> {
    scan_source(virtual_path, &fixture(name))
}

fn rules_of(vs: &[Violation]) -> Vec<&str> {
    vs.iter().map(|v| v.rule).collect()
}

#[test]
fn r1_flags_hash_containers_and_passes_ordered_ones() {
    let bad = scan_fixture("r1_bad.rs", "src/demo.rs");
    assert!(
        bad.iter().filter(|v| v.rule == "nondet-iteration").count() >= 2,
        "{bad:?}"
    );
    let good = scan_fixture("r1_good.rs", "src/demo.rs");
    assert!(good.is_empty(), "{good:?}");
}

#[test]
fn r2_flags_ad_hoc_threads_except_in_par() {
    let bad = scan_fixture("r2_bad.rs", "src/demo.rs");
    assert!(
        bad.iter().filter(|v| v.rule == "ad-hoc-thread").count() >= 2,
        "{bad:?}"
    );
    // the same source is exempt inside the pool implementation
    let exempt = scan_fixture("r2_bad.rs", "src/util/par.rs");
    assert!(
        exempt.iter().all(|v| v.rule != "ad-hoc-thread"),
        "{exempt:?}"
    );
    let good = scan_fixture("r2_good.rs", "src/demo.rs");
    assert!(good.is_empty(), "{good:?}");
}

#[test]
fn r3_flags_clocks_except_in_timer() {
    let bad = scan_fixture("r3_bad.rs", "src/demo.rs");
    assert!(
        bad.iter().filter(|v| v.rule == "ad-hoc-clock").count() >= 2,
        "{bad:?}"
    );
    let exempt = scan_fixture("r3_bad.rs", "src/util/timer.rs");
    assert!(exempt.iter().all(|v| v.rule != "ad-hoc-clock"), "{exempt:?}");
    let good = scan_fixture("r3_good.rs", "src/demo.rs");
    assert!(good.is_empty(), "{good:?}");
}

#[test]
fn r4_flags_os_entropy_but_not_seeded_rng() {
    let bad = scan_fixture("r4_bad.rs", "src/demo.rs");
    assert!(
        bad.iter().filter(|v| v.rule == "ad-hoc-entropy").count() >= 2,
        "{bad:?}"
    );
    let exempt = scan_fixture("r4_bad.rs", "src/util/rng.rs");
    assert!(
        exempt.iter().all(|v| v.rule != "ad-hoc-entropy"),
        "{exempt:?}"
    );
    let good = scan_fixture("r4_good.rs", "src/demo.rs");
    assert!(good.is_empty(), "{good:?}");
}

#[test]
fn r5_flags_panics_but_not_unwrap_or_family_or_tests_or_bins() {
    let bad = scan_fixture("r5_bad.rs", "src/demo.rs");
    assert!(
        bad.iter().filter(|v| v.rule == "panic-in-lib").count() >= 4,
        "{bad:?}"
    );
    // entrypoints may panic
    let in_bin = scan_fixture("r5_bad.rs", "src/bin/tool.rs");
    assert!(in_bin.iter().all(|v| v.rule != "panic-in-lib"), "{in_bin:?}");
    let in_main = scan_fixture("r5_bad.rs", "src/main.rs");
    assert!(
        in_main.iter().all(|v| v.rule != "panic-in-lib"),
        "{in_main:?}"
    );
    // unwrap_or / unwrap_or_else / unwrap_or_default and #[cfg(test)]
    // bodies are all fine
    let good = scan_fixture("r5_good.rs", "src/demo.rs");
    assert!(good.is_empty(), "{good:?}");
}

#[test]
fn r6_flags_sim_state_mutation_only_under_faults() {
    let bad = scan_fixture("r6_bad.rs", "src/faults/bad.rs");
    assert!(rules_of(&bad).contains(&"fault-hook-bypass"), "{bad:?}");
    // identical source outside src/faults/ is out of the rule's scope
    let elsewhere = scan_fixture("r6_bad.rs", "src/sim/engine.rs");
    assert!(
        elsewhere.iter().all(|v| v.rule != "fault-hook-bypass"),
        "{elsewhere:?}"
    );
    let good = scan_fixture("r6_good.rs", "src/faults/good.rs");
    assert!(good.is_empty(), "{good:?}");
}

#[test]
fn valid_suppressions_silence_their_rule() {
    let vs = scan_fixture("suppression_good.rs", "src/demo.rs");
    assert!(vs.is_empty(), "{vs:?}");
}

#[test]
fn bad_suppressions_are_flagged_and_inert() {
    let vs = scan_fixture("suppression_bad.rs", "src/demo.rs");
    let rules = rules_of(&vs);
    // each of the two functions yields the un-suppressed violation plus
    // the bad-suppression report
    assert_eq!(
        rules.iter().filter(|r| **r == "bad-suppression").count(),
        2,
        "{vs:?}"
    );
    assert_eq!(
        rules.iter().filter(|r| **r == "panic-in-lib").count(),
        2,
        "{vs:?}"
    );
}

/// The ratchet: the crate's own tree must be clean against the
/// checked-in baseline — no new violations AND no stale entries.
#[test]
fn self_scan_is_clean_against_baseline() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let violations = scan_tree(&root.join("src")).expect("scan src tree");
    let text = std::fs::read_to_string(root.join("lint-baseline.txt"))
        .expect("read lint-baseline.txt");
    let base = baseline::parse(&text).expect("parse baseline");
    let cmp = baseline::compare(&base, &violations);
    assert!(
        cmp.clean(),
        "lint drift: over = {:?}, stale = {:?}",
        cmp.over
            .iter()
            .map(|(d, vs)| format!("{}:{} ({} > {}): {vs:?}", d.path, d.rule, d.actual, d.allowed))
            .collect::<Vec<_>>(),
        cmp.stale
    );
}
