//! Integration: the online phase (ASM + monitor) against the live
//! simulator — convergence speed, re-tuning on load change, and the
//! end-to-end advantage over static choices.

use std::sync::OnceLock;
use twophase::logs::generator::{generate_history, GeneratorConfig};
use twophase::offline::pipeline::{KnowledgeBase, OfflineConfig};
use twophase::online::asm::AsmPhase;
use twophase::online::controller::DynamicTuner;
use twophase::sim::dataset::Dataset;
use twophase::sim::engine::SimEnv;
use twophase::sim::profile::NetProfile;
use twophase::Params;

fn kb() -> &'static KnowledgeBase {
    static KB: OnceLock<KnowledgeBase> = OnceLock::new();
    KB.get_or_init(|| {
        let logs = generate_history(
            &NetProfile::xsede(),
            &GeneratorConfig {
                days: 14.0,
                transfers_per_hour: 10.0,
                seed: 31,
            },
        );
        KnowledgeBase::build_native(logs, OfflineConfig::default())
    })
}

fn tuner_for(dataset: &Dataset) -> DynamicTuner {
    let p = NetProfile::xsede();
    let set = kb()
        .query(p.rtt_s, p.bandwidth_mbps, dataset.avg_file_mb, dataset.n_files)
        .expect("kb has surfaces")
        .clone();
    DynamicTuner::with_defaults(set)
}

#[test]
fn asm_converges_within_log2_buckets() {
    let dataset = Dataset::new(64, 512.0);
    let mut tuner = tuner_for(&dataset);
    let budget = tuner.asm().max_samples();
    let mut env = SimEnv::new(NetProfile::xsede(), 11).with_phase(3.0 * 3600.0);
    let mut prev: Option<Params> = None;
    let mut steps = 0;
    while tuner.phase() == AsmPhase::Sampling && steps < 20 {
        let params = tuner.params();
        let chunk = dataset.sample_chunk(0.01);
        let (th, _) = env.transfer_chunk(params, &chunk, prev);
        tuner.observe(th);
        prev = Some(params);
        steps += 1;
    }
    assert_eq!(tuner.phase(), AsmPhase::Streaming);
    assert!(
        tuner.samples_used() <= budget,
        "{} samples > budget {budget}",
        tuner.samples_used()
    );
    assert!(budget <= 4, "bucket count should keep the budget tiny");
}

#[test]
fn asm_transfer_beats_default_by_2x() {
    let dataset = Dataset::new(64, 512.0);
    let mut env_a = SimEnv::new(NetProfile::xsede(), 21).with_phase(3.0 * 3600.0);
    let mut tuner = tuner_for(&dataset);
    let asm_out = env_a.run_transfer(&dataset, 1024.0, |_, ctx| match ctx.last_throughput {
        None => tuner.params(),
        Some(th) => tuner.observe(th),
    });
    let mut env_b = SimEnv::new(NetProfile::xsede(), 21).with_phase(3.0 * 3600.0);
    let def_out = env_b.run_transfer(&dataset, 1024.0, |_, _| Params::DEFAULT);
    assert!(
        asm_out.avg_throughput_mbps() > 2.0 * def_out.avg_throughput_mbps(),
        "ASM {:.0} vs default {:.0}",
        asm_out.avg_throughput_mbps(),
        def_out.avg_throughput_mbps()
    );
}

#[test]
fn asm_retunes_on_harsh_load_change() {
    let dataset = Dataset::new(256, 256.0);
    let mut tuner = tuner_for(&dataset);
    // converge under honest feedback first
    let mut env = SimEnv::new(NetProfile::xsede(), 33).with_phase(3.0 * 3600.0);
    let mut prev = None;
    for _ in 0..4 {
        let params = tuner.params();
        let (th, _) = env.transfer_chunk(params, &dataset.sample_chunk(0.01), prev);
        tuner.observe(th);
        prev = Some(params);
    }
    assert_eq!(tuner.phase(), AsmPhase::Streaming);
    let before = tuner.asm().current_bucket();
    // harsh, persistent throughput collapse (external surge)
    for _ in 0..8 {
        tuner.observe(50.0);
    }
    assert!(tuner.retunes >= 1, "no re-tune after sustained collapse");
    assert!(
        tuner.asm().current_bucket() >= before,
        "should have moved to a heavier bucket"
    );
}

#[test]
fn asm_prediction_accuracy_is_high_after_convergence() {
    let dataset = Dataset::new(64, 512.0);
    let mut accs = Vec::new();
    for seed in 0..5u64 {
        let mut tuner = tuner_for(&dataset);
        let mut env = SimEnv::new(NetProfile::xsede(), 100 + seed).with_phase(3.0 * 3600.0);
        let mut prev = None;
        for _ in 0..4 {
            let params = tuner.params();
            let (th, _) = env.transfer_chunk(params, &dataset.sample_chunk(0.01), prev);
            tuner.observe(th);
            prev = Some(params);
        }
        // measure a validation chunk at the converged operating point
        let params = tuner.params();
        let (th, _) = env.transfer_chunk(params, &dataset.sample_chunk(0.02), prev);
        let acc = twophase::coordinator::metrics::accuracy_pct(th, tuner.predicted());
        accs.push(acc);
    }
    let mean = twophase::util::stats::mean(&accs);
    assert!(mean > 75.0, "mean converged accuracy {mean:.1}% too low");
}
