#!/usr/bin/env bash
# Tier-1 gate: build, test, lint from rust/ (see ROADMAP.md).
set -euo pipefail

cd "$(dirname "$0")/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found on PATH; install a Rust toolchain to run tier-1 checks" >&2
    exit 1
fi

cargo build --release
cargo test -q
cargo clippy -- -D warnings
