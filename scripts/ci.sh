#!/usr/bin/env bash
# Tier-1 gate: build, test, lint from rust/ (see ROADMAP.md).
set -euo pipefail

cd "$(dirname "$0")/../rust"

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found on PATH; install a Rust toolchain to run tier-1 checks" >&2
    exit 1
fi

cargo build --release
cargo test -q
cargo clippy -- -D warnings

# determinism & robustness lint: fails on violations not covered by
# rust/lint-baseline.txt AND on stale baseline entries (ratchet)
cargo run --release --bin pallas-lint -- --baseline

# second tier-1 pass under a fixed 2-worker pool: the deterministic
# thread pool must be bit-identical to serial, so nothing may change
PALLAS_THREADS=2 cargo test -q

# bench smoke: tiny grid through the parallelism bench, then make sure
# the emitted JSON actually parses
TWOPHASE_DAYS=2 TWOPHASE_REPS=1 PALLAS_THREADS=2 cargo bench --bench exp_parallel
if command -v python3 >/dev/null 2>&1; then
    python3 -c "import json; json.load(open('BENCH_parallel.json'))"
    echo "BENCH_parallel.json parses"
fi

# experiment fan-out determinism: the digest-equality prop binaries
# prove fig9 and generate_history bit-identical for 1/2/8 threads
PALLAS_THREADS=2 cargo test -q --test prop_fig9_parallel --test prop_history_parallel

# fig9 bench smoke: emits BENCH_fig9.json; the tracer's exported
# par.fanout_calls/units must match the bench's direct counter snapshot
TWOPHASE_DAYS=2 PALLAS_THREADS=2 cargo bench --bench exp_fig9_multiuser
if command -v python3 >/dev/null 2>&1; then
    python3 - <<'EOF'
import json
b = json.load(open('BENCH_fig9.json'))
assert b['digest_match'] is True, 'serial/parallel fig9 digests diverged'
f = b['fanout']
assert f['calls'] == f['calls_direct'] > 0, f
assert f['units'] == f['units_direct'] > 0, f
print('BENCH_fig9.json parses; fan-out counters agree '
      f"({int(f['calls'])} calls / {int(f['units'])} units)")
EOF
fi

# trace smoke: a tiny traced transfer must emit JSONL whose every line
# parses and whose schema (field names per record kind) matches the
# checked-in golden; `trace-schema --golden` exits nonzero on drift
rm -f TRACE_smoke.jsonl
cargo run --release --bin twophase -- transfer \
    --files 8 --avg-mb 64 --days 2 --trace TRACE_smoke.jsonl
test -s TRACE_smoke.jsonl
if command -v python3 >/dev/null 2>&1; then
    python3 -c "import json,sys; [json.loads(l) for l in open('TRACE_smoke.jsonl')]"
    echo "TRACE_smoke.jsonl parses"
fi
cargo run --release --bin twophase -- trace-schema TRACE_smoke.jsonl \
    --golden ../scripts/trace-schema.golden
rm -f TRACE_smoke.jsonl
