//! End-to-end validation driver (DESIGN.md §6): exercises the FULL
//! stack on a real small workload and reports the paper's headline
//! metrics.  All three layers compose here:
//!
//!   L1/L2 (build time): `make artifacts` lowered the JAX + Pallas
//!   surface pipeline to HLO text;
//!   L3 (run time): this binary loads the artifacts over PJRT, runs the
//!   offline phase through them, then serves a batch of transfer
//!   requests with every optimizer on three network profiles.
//!
//! Recorded in EXPERIMENTS.md.  Run with:
//!   `cargo run --release --example e2e_paper_run`

use std::sync::Arc;
use twophase::baselines::ann_ot::AnnOtModel;
use twophase::baselines::api::OptimizerKind;
use twophase::baselines::static_ann::StaticAnnModel;
use twophase::coordinator::orchestrator::{
    Orchestrator, OrchestratorConfig, TransferRequest,
};
use twophase::logs::generator::{generate_history, GeneratorConfig};
use twophase::offline::kmeans::NativeKmeans;
use twophase::offline::pipeline::{KnowledgeBase, OfflineConfig};
use twophase::offline::surface::NativeSurfaceBackend;
use twophase::runtime::accel::PjrtSurfaceBackend;
use twophase::runtime::engine::Engine;
use twophase::sim::dataset::Dataset;
use twophase::sim::profile::NetProfile;
use twophase::util::stats;
use twophase::util::table::Table;
use twophase::util::timer::time_once;

fn main() {
    println!("== end-to-end paper run ==\n");

    // ------------------------------------------------------ history --
    let mut logs = Vec::new();
    for p in NetProfile::all() {
        logs.extend(generate_history(
            &p,
            &GeneratorConfig {
                days: 14.0,
                transfers_per_hour: 8.0,
                seed: 0xB16_DA7A,
            },
        ));
    }
    println!("history: {} GridFTP-style entries across 4 networks", logs.len());

    // ------------------------------------------- offline (PJRT path) --
    let kb = match Engine::try_default() {
        Some(engine) => {
            println!("offline phase through the AOT JAX/Pallas artifacts (PJRT)...");
            let backend = PjrtSurfaceBackend::new(engine);
            let (kb, t) = time_once(|| {
                KnowledgeBase::build(
                    logs.clone(),
                    OfflineConfig::default(),
                    &backend,
                    &NativeKmeans,
                )
            });
            println!("  done in {t:?}: {} surfaces", kb.n_surfaces());
            kb
        }
        None => {
            println!("artifacts missing -> native offline phase (run `make artifacts`)");
            KnowledgeBase::build(
                logs.clone(),
                OfflineConfig::default(),
                &NativeSurfaceBackend,
                &NativeKmeans,
            )
        }
    };

    // ------------------------------------------------------- serving --
    let orch = Orchestrator::new(
        Arc::new(kb),
        Arc::new(StaticAnnModel::train(&logs, 32, 0xE1)),
        Arc::new(AnnOtModel::train(&logs, 32, 0xE2)),
        OrchestratorConfig::default(),
    )
    .expect("generated logs yield a non-empty knowledge base");

    let workloads = [
        ("xsede", Dataset::new(20_000, 1.0)),   // 20 GB of small files
        ("xsede", Dataset::new(64, 512.0)),     // 32 GB of large files
        ("didclab-xsede", Dataset::new(256, 64.0)), // 16 GB medium
    ];
    let models = [
        OptimizerKind::Asm,
        OptimizerKind::Harp,
        OptimizerKind::Globus,
        OptimizerKind::NoOpt,
    ];

    let mut table = Table::new(&["workload", "ASM", "HARP", "GO", "NoOpt", "ASM/HARP", "ASM/NoOpt"]);
    let mut asm_vs_harp = Vec::new();
    let mut asm_vs_noopt = Vec::new();
    let mut id = 0;
    for (net, dataset) in &workloads {
        let mut cells = Vec::new();
        for model in models {
            let mut ths = Vec::new();
            for rep in 0..3u64 {
                id += 1;
                let req = TransferRequest {
                    id,
                    profile: NetProfile::by_name(net).unwrap(),
                    dataset: dataset.clone(),
                    model,
                    seed: 0xE2E ^ (id + rep),
                    phase_s: 3.0 * 3600.0,
                };
                ths.push(orch.execute(&req).avg_throughput_mbps);
            }
            cells.push(stats::mean(&ths));
        }
        let r_harp = cells[0] / cells[1].max(1e-9);
        let r_noopt = cells[0] / cells[3].max(1e-9);
        asm_vs_harp.push(r_harp);
        asm_vs_noopt.push(r_noopt);
        table.row(&[
            format!("{net} {:.0}MBx{}", dataset.avg_file_mb, dataset.n_files),
            format!("{:.0}", cells[0]),
            format!("{:.0}", cells[1]),
            format!("{:.0}", cells[2]),
            format!("{:.0}", cells[3]),
            format!("{r_harp:.2}x"),
            format!("{r_noopt:.2}x"),
        ]);
    }
    println!("\nend-to-end achieved throughput (Mbps, mean of 3 seeds):");
    table.print();
    println!(
        "headline: ASM vs HARP geo-mean {:.2}x (paper 1.2-1.7x), vs NoOpt {:.1}x (paper ~5x)",
        geo_mean(&asm_vs_harp),
        geo_mean(&asm_vs_noopt)
    );
}

fn geo_mean(xs: &[f64]) -> f64 {
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}
