//! Offline-phase walkthrough: what the knowledge discovery actually
//! produces — clusters, load buckets, throughput surfaces, maxima and
//! sampling regions — and the PJRT-accelerated path when artifacts are
//! built.
//!
//! Run with: `cargo run --release --example offline_analysis`

use twophase::logs::generator::{generate_history, GeneratorConfig};
use twophase::offline::kmeans::NativeKmeans;
use twophase::offline::maxima::find_local_maxima;
use twophase::offline::pipeline::{KnowledgeBase, OfflineConfig};
use twophase::offline::surface::NativeSurfaceBackend;
use twophase::runtime::accel::PjrtSurfaceBackend;
use twophase::runtime::engine::Engine;
use twophase::sim::profile::NetProfile;
use twophase::util::timer::time_once;

fn main() {
    println!("== offline knowledge discovery ==\n");
    let mut logs = Vec::new();
    for p in [NetProfile::xsede(), NetProfile::didclab_xsede()] {
        logs.extend(generate_history(
            &p,
            &GeneratorConfig {
                days: 10.0,
                transfers_per_hour: 8.0,
                seed: 0xB16_DA7A,
            },
        ));
    }
    println!("log corpus: {} entries over 10 days, 2 networks", logs.len());

    // native build
    let (kb, native_t) = time_once(|| {
        KnowledgeBase::build(
            logs.clone(),
            OfflineConfig::default(),
            &NativeSurfaceBackend,
            &NativeKmeans,
        )
    });
    println!(
        "native offline phase: {:?} -> k={} ({:?}, CH={:.0}), {} surface sets",
        native_t,
        kb.clustering.k,
        kb.clustering.algo,
        kb.clustering.ch_score,
        kb.sets.len()
    );

    // PJRT-accelerated build (same result, AOT JAX/Pallas artifacts)
    if let Some(engine) = Engine::try_default() {
        let backend = PjrtSurfaceBackend::new(engine);
        let (kb2, pjrt_t) = time_once(|| {
            KnowledgeBase::build(
                logs.clone(),
                OfflineConfig::default(),
                &backend,
                &NativeKmeans,
            )
        });
        println!(
            "PJRT offline phase:   {:?} -> {} surfaces (parity with native: {})",
            pjrt_t,
            kb2.n_surfaces(),
            kb2.n_surfaces() == kb.n_surfaces()
        );
    } else {
        println!("(artifacts not built; run `make artifacts` for the PJRT path)");
    }

    // inspect one surface set
    let p = NetProfile::xsede();
    let set = kb.query(p.rtt_s, p.bandwidth_mbps, 512.0, 64).unwrap();
    println!(
        "\nquery(xsede, 512 MB files) -> cluster {} / class {:?}:",
        set.cluster, set.class
    );
    for b in &set.buckets {
        println!(
            "  bucket {} (load {:.2}): optimum {} -> {:.0} Mbps over {} pp-slices",
            b.bucket,
            b.load_intensity,
            b.optimal_params,
            b.optimal_th,
            b.slices.len()
        );
        if let Some(s) = b.slices.first() {
            let maxima = find_local_maxima(&s.fitted.surface, 8);
            println!(
                "    pp={} slice: {} local maxima (Hessian-tested), sigma={:.1}",
                s.pp,
                maxima.len(),
                s.confidence.sigma
            );
        }
    }
    println!(
        "  sampling region R_s: {} points ({} from maxima)",
        set.sampling.len(),
        set.sampling.iter().filter(|q| q.from_maxima).count()
    );
}
