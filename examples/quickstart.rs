//! Quickstart: the smallest end-to-end use of the public API.
//!
//! 1. generate a short synthetic GridFTP history on the XSEDE profile;
//! 2. run the offline phase (cluster → surfaces → maxima → regions);
//! 3. transfer a dataset with the two-phase optimizer and compare it
//!    against the no-optimization default.
//!
//! Run with: `cargo run --release --example quickstart`

use twophase::baselines::api::OptimizerKind;
use twophase::coordinator::orchestrator::TransferRequest;
use twophase::experiments::common::{ctx, OFFPEAK_PHASE_S};
use twophase::sim::dataset::Dataset;
use twophase::sim::profile::NetProfile;

fn main() {
    println!("== twophase quickstart ==\n");
    println!("building knowledge base from synthetic history (one-time)...");
    let c = ctx(); // generates logs + runs the offline phase + trains baselines

    println!(
        "offline phase: {} log entries -> {} clusters -> {} surfaces\n",
        c.kb.n_entries(),
        c.kb.clustering.k,
        c.kb.n_surfaces()
    );

    let dataset = Dataset::new(64, 512.0); // 32 GB of 512 MB files
    for model in [OptimizerKind::Asm, OptimizerKind::NoOpt] {
        let req = TransferRequest {
            id: 1,
            profile: NetProfile::xsede(),
            dataset: dataset.clone(),
            model,
            seed: 7,
            phase_s: OFFPEAK_PHASE_S,
        };
        let r = c.orchestrator.execute(&req);
        println!(
            "{:<6} avg={:>7.1} Mbps  duration={:>7.1}s  samples={}  final={}",
            r.model, r.avg_throughput_mbps, r.duration_s, r.sample_transfers, r.final_params
        );
    }
    println!("\nThe two-phase model should be several times faster than the default.");
}
