//! Multi-user fairness scenario (§5.4): users share the Chameleon
//! bottleneck, all running the same optimizer; compares ASM, HARP, GO
//! and the default across aggregate throughput and per-user fairness,
//! swept over user counts with the paper's four as the headline.
//!
//! Run with: `cargo run --release --example multiuser_fairness`

use twophase::baselines::api::OptimizerKind;
use twophase::experiments::fig9;
use twophase::util::stats;

fn main() {
    println!("== multi-user fairness (Chameleon) ==\n");
    let res = fig9::run();

    println!(
        "\nper-user time-mean shares and Jain indices at {} users:",
        fig9::USERS_PAPER
    );
    for row in res.rows.iter().filter(|r| r.users == fig9::USERS_PAPER) {
        println!(
            "  {:<6} jain={:.3}  per-user σ={:>7.1} Mbps",
            row.model.label(),
            row.jain,
            row.stddev_mbps
        );
    }

    let asm = res.aggregate(OptimizerKind::Asm);
    let noopt = res.aggregate(OptimizerKind::NoOpt);
    println!(
        "\nheadline: ASM aggregate = {:.0} Mbps = {:.1}x the no-optimization default",
        asm,
        asm / noopt.max(1e-9)
    );
    let asm_users: Vec<f64> = res
        .row(OptimizerKind::Asm, fig9::USERS_PAPER)
        .map(|r| r.per_user_mbps.clone())
        .unwrap_or_default();
    println!(
        "ASM fairness: Jain index {:.3} across users {:?}",
        stats::jain_index(&asm_users),
        asm_users.iter().map(|v| v.round()).collect::<Vec<_>>()
    );
}
