//! Fault injection and recovery: transfer through a deterministic
//! storm of network faults and watch the recovery machinery work.
//!
//! 1. generate a seeded `FaultPlan` (link degradation, loss bursts,
//!    RTT inflation, traffic surges, endpoint stalls);
//! 2. run the same transfer clean and faulted for the two-phase model
//!    and two static baselines;
//! 3. compare recovered throughput fractions and the retry/backoff
//!    traces.
//!
//! Run with: `cargo run --release --example fault_recovery`

use twophase::baselines::api::OptimizerKind;
use twophase::coordinator::orchestrator::TransferRequest;
use twophase::experiments::common::{ctx, OFFPEAK_PHASE_S};
use twophase::faults::{FaultPlan, FaultPlanConfig};
use twophase::sim::dataset::Dataset;
use twophase::sim::profile::NetProfile;

fn main() {
    println!("== twophase fault recovery ==\n");
    let c = ctx(); // knowledge base + baselines (one-time)

    let profile = NetProfile::xsede();
    let cfg = FaultPlanConfig {
        events_per_hour: 60.0,
        ..FaultPlanConfig::with_intensity(0.7)
    };
    let plan = FaultPlan::generate(&profile, &cfg, 0xBAD_DA7);
    println!("fault schedule ({} events in the first hour shown):", plan.len());
    for e in plan.events.iter().take(8) {
        println!(
            "  t={:>6.0}s  {:<16} magnitude={:.3} for {:.0}s",
            e.t_start_s,
            e.kind.name(),
            e.magnitude,
            e.duration_s
        );
    }
    println!();

    let dataset = Dataset::new(256, 512.0); // 128 GB
    for model in [
        OptimizerKind::Asm,
        OptimizerKind::Harp,
        OptimizerKind::Globus,
    ] {
        let req = TransferRequest {
            id: 1,
            profile: profile.clone(),
            dataset: dataset.clone(),
            model,
            seed: 7,
            phase_s: OFFPEAK_PHASE_S,
        };
        let clean = c.orchestrator.execute(&req);
        let rr = c.orchestrator.execute_with_faults(&req, Some(plan.clone()));
        println!(
            "{:<6} clean={:>7.1} Mbps  faulted={:>7.1} Mbps  recovered={:>4.0}%  \
             retries={} backoff={:.0}s resumed={} {}",
            clean.model,
            clean.avg_throughput_mbps,
            rr.report.avg_throughput_mbps,
            100.0 * rr.report.avg_throughput_mbps / clean.avg_throughput_mbps.max(1e-9),
            rr.retries,
            rr.backoff_total_s,
            rr.resumed_chunks,
            if rr.completed { "" } else { "(FAILED)" },
        );
    }
    println!(
        "\nThe two-phase model re-tunes after confirmed faults, so it should \
         keep the largest fraction of its clean throughput."
    );
}
